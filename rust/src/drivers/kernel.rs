//! Kernel-level driver (§III.B): an ioctl front end over the Xilinx
//! AXI-DMA dmaengine driver.
//!
//! Per transfer, the application makes one syscall handing the driver a
//! virtual-space payload. The driver `copy_from_user`s it into cached
//! kernel bounce buffers, performs the dma_map cache *clean* (TX) /
//! *invalidate* (RX) — the per-byte toll of coherent DMA on the A9 —
//! builds scatter-gather BD chains, blocks the task, and is woken by the
//! completion interrupts (GIC → ISR → wake → context switch).
//!
//! Two operating shapes, selected by the user-visible buffering/
//! partitioning knobs to match the paper's two measurement setups:
//!
//! * **Pipelined SG** (default; what the Xilinx driver does for long
//!   requests by "dividing them into small pieces and queuing them into
//!   consecutive transfers — Scatter-gated mode"): chunk *i+1*'s
//!   copy+flush overlaps chunk *i*'s DMA. This is the Fig. 4/5 kernel
//!   curve that amortises its fixed costs and wins for large transfers.
//! * **Worst case** (`Single` buffer + `Unique` partition — exactly the
//!   configuration Table I reports: "tested for the worst possible case:
//!   single buffer scheme and unique data transfers"): the whole payload
//!   is copied + flushed, *then* the chain is submitted. No overlap —
//!   which is why the kernel row of Table I loses to user-level polling
//!   at RoShamBo's ~100 KB transfer lengths.
//!
//! The blocking `transfer` is `submit` (arm + feed the engine)
//! followed by `complete` (block on the IRQs, invalidate + copy out) —
//! the split-phase pair the frame-pipelined coordinator drives directly.
//!
//! `transfer_multiqueue` is the multi-engine extension: the same
//! pipelined SG feed, but chunks are striped round-robin across *every*
//! engine's MM2S queue (and the RX arms split proportionally), so a
//! single payload exploits all PS–PL ports concurrently — NEURAghe's
//! trick. The CPU-side copy+flush feed is still serial (one core), so
//! striping pays exactly when the per-engine stream is the bottleneck.

use crate::axi::descriptor::{chain_into, Descriptor, MAX_DESC_LEN};
use crate::axi::dma::DmaMode;
use crate::axi::regs;
use crate::memory::buffer::PhysAddr;
use crate::memory::copy::CopyKind;
use crate::sim::event::{Channel, EngineId};
use crate::sim::fault::DmaErrorKind;
use crate::sim::time::Dur;
use crate::system::{CpuLedger, System, WaitVerdict};

use super::scheme::SubmitToken;
use super::{BufferScheme, Driver, DriverError, PartitionMode, TransferOutcome, TransferReport};

/// dma_map_single cache-maintenance time for `bytes`.
fn flush_time(sys: &System, bytes: u64) -> Dur {
    Dur::for_bytes(bytes, sys.cfg.kernel_cache_flush_bps)
}

/// Hand a completed RX payload back to user space: copy-through runs
/// the per-chunk dma_unmap invalidate + `copy_to_user` loop; zero-copy
/// charges the port's coherency cost and returns the frame in place.
fn rx_handoff(sys: &mut System, rx_bytes: u64) {
    if sys.cfg.memory.is_zero_copy() {
        sys.coherency_rx(rx_bytes);
        return;
    }
    let sg_chunk = sys.cfg.kernel_sg_chunk_bytes;
    let mut left = rx_bytes;
    while left > 0 {
        let len = sg_chunk.min(left);
        let fl = flush_time(sys, len);
        sys.cpu_exec(fl); // dma_unmap invalidate
        sys.cpu_copy(len, CopyKind::KernelCached);
        left -= len;
    }
}

pub(super) fn transfer(
    drv: &mut Driver,
    sys: &mut System,
    tx_bytes: u64,
    rx_bytes: u64,
) -> Result<TransferReport, DriverError> {
    let token = submit(drv, sys, tx_bytes, rx_bytes)?;
    complete(drv, sys, token)
}

/// Arm the RX scatter-gather chain for `bytes` starting `offset` into
/// the RX bounce window (descriptor build per BD; the buffer is
/// invalidated before the copy-out instead — see [`complete`]). Chains
/// build into the system's recycled scratch buffer: no per-transfer
/// allocation once warm. `offset == 0` is the normal submit; recovery
/// re-arms the engine-reported residue at its offset.
fn arm_rx_chain(drv: &Driver, sys: &mut System, offset: u64, bytes: u64) {
    let sg_chunk = sys.cfg.kernel_sg_chunk_bytes;
    let mut descs = sys.take_desc_scratch();
    chain_into(PhysAddr(drv.rx_buf(0).addr.0 + offset), bytes, sg_chunk, &mut descs);
    sys.cpu_exec(Dur(descs.len() as u64 * sys.cfg.kernel_desc_build_ns));
    sys.program_dma_slice_on(drv.port, Channel::S2mm, DmaMode::ScatterGather, &descs);
    sys.put_desc_scratch(descs);
}

/// Zero-copy TX arm: build and submit the SG chain over the in-place
/// region — no copy, no flush (coherency was charged at submit). Used on
/// the fault-active zero-copy path and by recovery, where partial
/// residues rule out the fixed ring template.
fn arm_tx_chain(drv: &Driver, sys: &mut System, offset: u64, bytes: u64) {
    let sg_chunk = sys.cfg.kernel_sg_chunk_bytes;
    let mut descs = sys.take_desc_scratch();
    chain_into(PhysAddr(drv.tx_buf(0).addr.0 + offset), bytes, sg_chunk, &mut descs);
    sys.cpu_exec(Dur(descs.len() as u64 * sys.cfg.kernel_desc_build_ns));
    sys.program_dma_slice_on(drv.port, Channel::Mm2s, DmaMode::ScatterGather, &descs);
    sys.put_desc_scratch(descs);
}

/// Copy/flush/feed `bytes` of TX payload starting `offset` into the
/// stream, in the driver's configured shape (worst case: whole payload
/// copied + cleaned, then one chain; pipelined: per-chunk overlap).
/// Recovery re-feeds the residue with fresh copies — the bounce ring
/// only holds the last two chunks, so a resubmission re-stages from
/// user memory exactly like the real driver's retried request.
fn feed_tx(drv: &Driver, sys: &mut System, offset: u64, bytes: u64, worst_case: bool) {
    let sg_chunk = sys.cfg.kernel_sg_chunk_bytes;
    let port = drv.port;
    if worst_case {
        // Copy + clean the whole payload, then submit the chain.
        sys.cpu_copy(bytes, CopyKind::KernelCached);
        let fl = flush_time(sys, bytes);
        sys.cpu_exec(fl);
        let mut descs = sys.take_desc_scratch();
        chain_into(PhysAddr(drv.tx_buf(0).addr.0 + offset), bytes, sg_chunk, &mut descs);
        sys.cpu_exec(Dur(descs.len() as u64 * sys.cfg.kernel_desc_build_ns));
        sys.program_dma_slice_on(port, Channel::Mm2s, DmaMode::ScatterGather, &descs);
        sys.put_desc_scratch(descs);
    } else {
        // Pipelined: copy/flush chunk i+1 while the engine DMAs chunk i.
        let mut off = 0u64;
        let mut i = 0usize;
        let mut programmed = false;
        while off < bytes {
            let len = sg_chunk.min(bytes - off);
            sys.cpu_copy(len, CopyKind::KernelCached);
            let fl = flush_time(sys, len);
            sys.cpu_exec(fl);
            sys.cpu_exec(Dur(sys.cfg.kernel_desc_build_ns));
            let last = off + len == bytes;
            let mut d = Descriptor::new(drv.tx_buf(i).addr, len);
            if last {
                d = d.with_irq();
            }
            if !programmed {
                sys.program_dma_slice_on(port, Channel::Mm2s, DmaMode::ScatterGather, &[d]);
                programmed = true;
            } else {
                sys.append_dma_slice_on(port, Channel::Mm2s, &[d]);
            }
            off += len;
            i += 1;
        }
    }
}

/// Split-phase entry: ioctl entry, RX chain arm, TX copy/flush/feed.
/// Everything up to (not including) the completion waits.
pub(super) fn submit(
    drv: &mut Driver,
    sys: &mut System,
    tx_bytes: u64,
    rx_bytes: u64,
) -> Result<SubmitToken, DriverError> {
    if sys.cfg.memory.is_zero_copy() {
        return submit_zero_copy(drv, sys, tx_bytes, rx_bytes);
    }
    let worst_case = drv.cfg.buffering == BufferScheme::Single
        && drv.cfg.partition == PartitionMode::Unique;
    let t0 = sys.now();

    // ioctl entry + argument marshalling + dmaengine channel setup.
    let entry = sys.costs.syscall_entry();
    sys.cpu_exec(entry);
    sys.cpu_exec(Dur(sys.cfg.kernel_submit_ns));

    // Arm the whole RX chain up front, then feed the TX side.
    if rx_bytes > 0 {
        arm_rx_chain(drv, sys, 0, rx_bytes);
    }
    feed_tx(drv, sys, 0, tx_bytes, worst_case);
    Ok(SubmitToken { t0, tx_bytes, rx_bytes })
}

/// Zero-copy ioctl submit: the frame already lives in the in-place DMA
/// region, so there is no `copy_from_user` and no bounce-buffer flush —
/// only the port's coherency cost ([`System::coherency_tx`], the
/// dma_map of the user pages). The first frame of a shape arms cyclic
/// SG rings; later same-shape frames re-trigger them with one doorbell
/// write per direction. With the fault plan active the rings are
/// bypassed for per-frame chains, which recovery can rebuild at partial
/// residues.
fn submit_zero_copy(
    drv: &mut Driver,
    sys: &mut System,
    tx_bytes: u64,
    rx_bytes: u64,
) -> Result<SubmitToken, DriverError> {
    let t0 = sys.now();
    let port = drv.port;

    let entry = sys.costs.syscall_entry();
    sys.cpu_exec(entry);
    sys.cpu_exec(Dur(sys.cfg.kernel_submit_ns));
    sys.coherency_tx(tx_bytes);

    if sys.faults.is_active() {
        drv.armed = None;
        if rx_bytes > 0 {
            arm_rx_chain(drv, sys, 0, rx_bytes);
        }
        arm_tx_chain(drv, sys, 0, tx_bytes);
        return Ok(SubmitToken { t0, tx_bytes, rx_bytes });
    }

    if drv.armed == Some((tx_bytes, rx_bytes)) {
        if rx_bytes > 0 {
            sys.ring_trigger_on(port, Channel::S2mm);
        }
        sys.ring_trigger_on(port, Channel::Mm2s);
    } else {
        arm_rings(drv, sys, tx_bytes, rx_bytes);
    }
    Ok(SubmitToken { t0, tx_bytes, rx_bytes })
}

/// Build and arm the cyclic SG rings for one frame shape (RX first).
/// BD construction is charged per descriptor; the rings survive across
/// frames until a shape change or a recovery reset disarms them.
fn arm_rings(drv: &mut Driver, sys: &mut System, tx_bytes: u64, rx_bytes: u64) {
    let chunk = sys.cfg.memory.ring_chunk_bytes.min(MAX_DESC_LEN);
    let port = drv.port;
    let mut descs = sys.take_desc_scratch();
    if rx_bytes > 0 {
        chain_into(drv.rx_buf(0).addr, rx_bytes, chunk, &mut descs);
        sys.cpu_exec(Dur(descs.len() as u64 * sys.cfg.kernel_desc_build_ns));
        sys.program_dma_ring_on(port, Channel::S2mm, &descs);
    }
    chain_into(drv.tx_buf(0).addr, tx_bytes, chunk, &mut descs);
    sys.cpu_exec(Dur(descs.len() as u64 * sys.cfg.kernel_desc_build_ns));
    sys.program_dma_ring_on(port, Channel::Mm2s, &descs);
    sys.put_desc_scratch(descs);
    drv.armed = Some((tx_bytes, rx_bytes));
}

/// Bounded re-submission after a channel error: dmaengine terminates
/// the descriptor ring (modelled as the `DMACR.Reset` write), then the
/// unfinished residue is rebuilt and resubmitted at its offset.
#[allow(clippy::too_many_arguments)]
fn kernel_recover(
    drv: &Driver,
    sys: &mut System,
    ch: Channel,
    kind: DmaErrorKind,
    tx_bytes: u64,
    rx_bytes: u64,
    worst_case: bool,
    retries: &mut u32,
    recovery_ns: &mut u64,
) -> Result<(), DriverError> {
    let limit = sys.cfg.faults.retry_limit_u32();
    if *retries >= limit {
        return Err(DriverError::Faulted {
            ch: ch.paper_name(),
            retries: *retries,
            kind: Some(kind),
        });
    }
    let t0 = sys.now();
    let total = match ch {
        Channel::Mm2s => tx_bytes,
        Channel::S2mm => rx_bytes,
    };
    let residue = sys.port(drv.port).chan(ch).residue();
    debug_assert!(residue > 0 && residue <= total, "residue {residue} of {total}");
    let done = total - residue;
    sys.mmio_write_on(drv.port, regs::dmacr_offset(ch), regs::CR_RESET)
        .expect("CR_RESET write");
    match ch {
        Channel::S2mm => arm_rx_chain(drv, sys, done, residue),
        // Zero-copy frames are never staged, so a TX retry just rebuilds
        // the chain over the residue tail of the in-place region.
        Channel::Mm2s if sys.cfg.memory.is_zero_copy() => arm_tx_chain(drv, sys, done, residue),
        Channel::Mm2s => feed_tx(drv, sys, done, residue, worst_case),
    }
    *retries += 1;
    *recovery_ns += sys.now().since(t0).ns();
    Ok(())
}

/// Watchdog rescue of a lost completion interrupt: the driver reads the
/// engine state directly and, if the chain is done, W1C-clears both the
/// engine latch and the register file's `SR_IOC_IRQ` (which the
/// dispatcher latched before the edge was dropped). Returns `true` when
/// the channel was indeed complete; shared by the single- and
/// multi-queue waits so the rescue protocol cannot drift.
fn try_rescue_lost_ioc(sys: &mut System, e: EngineId, ch: Channel) -> bool {
    sys.cpu_exec(Dur(sys.cfg.reg_read_ns));
    if !sys.port(e).chan(ch).is_done() {
        return false;
    }
    sys.mmio_write_on(e, regs::dmasr_offset(ch), regs::SR_IOC_IRQ).expect("SR W1C write");
    true
}

/// Interrupt wait with the kernel's recovery machinery: the error-IRQ
/// path resubmits the residue (bounded by `faults.retry_limit`), and a
/// `wait_event_timeout` expiry lets the driver inspect the engine
/// directly — rescuing lost completion interrupts and reviving a wait
/// starved by the peer channel's death, the two cases user space cannot
/// handle safely (the paper's §V safety argument, made executable).
#[allow(clippy::too_many_arguments)]
fn kernel_wait(
    drv: &Driver,
    sys: &mut System,
    ch: Channel,
    tx_bytes: u64,
    rx_bytes: u64,
    worst_case: bool,
    retries: &mut u32,
    recovery_ns: &mut u64,
) -> Result<(), DriverError> {
    let limit = sys.cfg.faults.retry_limit_u32();
    let timeout = Dur(sys.cfg.faults.timeout_ns);
    let port = drv.port;
    loop {
        match sys.irq_wait_timeout_on(port, ch, timeout)? {
            WaitVerdict::Done => return Ok(()),
            WaitVerdict::Fault(kind) => {
                kernel_recover(
                    drv, sys, ch, kind, tx_bytes, rx_bytes, worst_case, retries, recovery_ns,
                )?;
            }
            WaitVerdict::TimedOut => {
                // The ISR never ran: inspect the engine directly.
                let t_rescue = sys.now();
                if try_rescue_lost_ioc(sys, port, ch) {
                    // Completion IRQ lost; rescued by the watchdog. The
                    // recovery latency is the watchdog window the task
                    // sat wedged, plus the rescue actions themselves.
                    *retries += 1;
                    *recovery_ns += timeout.ns() + sys.now().since(t_rescue).ns();
                    return Ok(());
                }
                if let Some(kind) = sys.port(port).chan(ch).error() {
                    // Error IRQ lost; recover as if it had been delivered.
                    sys.port_mut(port).chan_mut(ch).ack_err_irq();
                    kernel_recover(
                        drv, sys, ch, kind, tx_bytes, rx_bytes, worst_case, retries,
                        recovery_ns,
                    )?;
                    continue;
                }
                let peer = match ch {
                    Channel::Mm2s => Channel::S2mm,
                    Channel::S2mm => Channel::Mm2s,
                };
                if let Some(kind) = sys.port(port).chan(peer).error() {
                    // The peer channel died and starved this one.
                    kernel_recover(
                        drv, sys, peer, kind, tx_bytes, rx_bytes, worst_case, retries,
                        recovery_ns,
                    )?;
                } else if *retries >= limit {
                    return Err(DriverError::Faulted {
                        ch: ch.paper_name(),
                        retries: *retries,
                        kind: None,
                    });
                } else {
                    // Nothing attributable: burn one bounded watchdog
                    // round and keep waiting.
                    *retries += 1;
                }
            }
        }
    }
}

/// Split-phase completion: block on the TX then RX interrupts, then
/// invalidate + copy the payload out and return to user space. With an
/// active fault plan the waits run through [`kernel_wait`]'s error-IRQ +
/// watchdog recovery; otherwise this is exactly the seed's code path.
pub(super) fn complete(
    drv: &mut Driver,
    sys: &mut System,
    token: SubmitToken,
) -> Result<TransferReport, DriverError> {
    if sys.faults.is_active() {
        return complete_recover(drv, sys, token);
    }
    let SubmitToken { t0, tx_bytes, rx_bytes } = token;
    let port = drv.port;

    // Block until the TX completion interrupt.
    sys.irq_wait_on(port, Channel::Mm2s)?;
    let tx_time = sys.now().since(t0);

    // Block until RX completes, then hand the payload back.
    let rx_time = if rx_bytes > 0 {
        sys.irq_wait_on(port, Channel::S2mm)?;
        rx_handoff(sys, rx_bytes);
        let exit = sys.costs.syscall_exit();
        sys.cpu_exec(exit);
        sys.now().since(t0)
    } else {
        let exit = sys.costs.syscall_exit();
        sys.cpu_exec(exit);
        Dur::ZERO
    };

    Ok(TransferReport {
        tx_bytes,
        rx_bytes,
        tx_time,
        rx_time,
        ledger: CpuLedger::default(),
        outcome: TransferOutcome::Completed,
    })
}

/// [`complete`] with the error-IRQ handler + watchdog recovery engaged.
fn complete_recover(
    drv: &mut Driver,
    sys: &mut System,
    token: SubmitToken,
) -> Result<TransferReport, DriverError> {
    let SubmitToken { t0, tx_bytes, rx_bytes } = token;
    let worst_case = drv.cfg.buffering == BufferScheme::Single
        && drv.cfg.partition == PartitionMode::Unique;
    let mut retries = 0u32;
    let mut recovery_ns = 0u64;

    kernel_wait(
        drv,
        sys,
        Channel::Mm2s,
        tx_bytes,
        rx_bytes,
        worst_case,
        &mut retries,
        &mut recovery_ns,
    )?;
    let tx_time = sys.now().since(t0);

    let rx_time = if rx_bytes > 0 {
        kernel_wait(
            drv,
            sys,
            Channel::S2mm,
            tx_bytes,
            rx_bytes,
            worst_case,
            &mut retries,
            &mut recovery_ns,
        )?;
        rx_handoff(sys, rx_bytes);
        let exit = sys.costs.syscall_exit();
        sys.cpu_exec(exit);
        sys.now().since(t0)
    } else {
        let exit = sys.costs.syscall_exit();
        sys.cpu_exec(exit);
        Dur::ZERO
    };

    let outcome = if retries == 0 {
        TransferOutcome::Completed
    } else {
        TransferOutcome::Recovered { retries, recovery_ns }
    };
    Ok(TransferReport { tx_bytes, rx_bytes, tx_time, rx_time, ledger: CpuLedger::default(), outcome })
}

/// Multi-queue completion wait: legacy blocking wait when the fault
/// plan is inactive; with faults active, fail fast on an error or an
/// unattributable timeout, and rescue lost completion IRQs through the
/// watchdog (full residue re-submission across stripes is future work —
/// the single-queue kernel scheme is the recovery reference).
fn mq_wait(
    sys: &mut System,
    e: EngineId,
    ch: Channel,
    rescues: &mut u32,
    recovery_ns: &mut u64,
) -> Result<(), DriverError> {
    if !sys.faults.is_active() {
        sys.irq_wait_on(e, ch)?;
        return Ok(());
    }
    let timeout = Dur(sys.cfg.faults.timeout_ns);
    match sys.irq_wait_timeout_on(e, ch, timeout)? {
        WaitVerdict::Done => Ok(()),
        WaitVerdict::Fault(kind) => Err(DriverError::Faulted {
            ch: ch.paper_name(),
            retries: *rescues,
            kind: Some(kind),
        }),
        WaitVerdict::TimedOut => {
            let t_rescue = sys.now();
            if try_rescue_lost_ioc(sys, e, ch) {
                *rescues += 1;
                *recovery_ns += timeout.ns() + sys.now().since(t_rescue).ns();
                return Ok(());
            }
            let kind = sys.port(e).chan(ch).error();
            Err(DriverError::Faulted { ch: ch.paper_name(), retries: *rescues, kind })
        }
    }
}

/// Multi-queue kernel transfer: stripe the SG chunks across every
/// engine round-robin, arm each engine's RX for its proportional share,
/// feed the chunks in submission order, then collect every completion
/// interrupt. With loop-back devices each engine echoes exactly its own
/// stripe, so per-engine RX = per-engine TX share.
pub(super) fn transfer_multiqueue(
    drv: &mut Driver,
    sys: &mut System,
    tx_bytes: u64,
    rx_bytes: u64,
) -> Result<TransferReport, DriverError> {
    let n = sys.num_ports();
    let sg_chunk = sys.cfg.kernel_sg_chunk_bytes;
    let t0 = sys.now();

    // Plan the stripes: chunk i goes to engine i % n.
    let mut tx_share = vec![0u64; n];
    let mut chunks_of = vec![0usize; n];
    {
        let mut off = 0u64;
        let mut i = 0usize;
        while off < tx_bytes {
            let len = sg_chunk.min(tx_bytes - off);
            tx_share[i % n] += len;
            chunks_of[i % n] += 1;
            off += len;
            i += 1;
        }
    }
    // RX shares proportional to TX shares (exact for loop-back, where
    // each engine's device echoes its own stripe); the last active
    // engine absorbs the rounding remainder.
    let mut rx_share = vec![0u64; n];
    if rx_bytes > 0 {
        let mut assigned = 0u64;
        let mut last_active = 0usize;
        for p in 0..n {
            if tx_share[p] == 0 {
                continue;
            }
            rx_share[p] = rx_bytes * tx_share[p] / tx_bytes;
            assigned += rx_share[p];
            last_active = p;
        }
        rx_share[last_active] += rx_bytes - assigned;
    }

    // ioctl entry + argument marshalling + one dmaengine submit per
    // engine used.
    let entry = sys.costs.syscall_entry();
    sys.cpu_exec(entry);
    let engines_used = tx_share.iter().filter(|&&s| s > 0).count() as u64;
    sys.cpu_exec(Dur(engines_used.max(1) * sys.cfg.kernel_submit_ns));
    // Zero-copy: one dma_map of the whole in-place frame up front; the
    // per-stripe copy+flush below is gated off.
    let zero_copy = sys.cfg.memory.is_zero_copy();
    if zero_copy {
        sys.coherency_tx(tx_bytes);
    }

    // Arm every engine's RX chain up front (one recycled chain buffer
    // reused across engines).
    let mut descs = sys.take_desc_scratch();
    for p in 0..n {
        if rx_share[p] == 0 {
            continue;
        }
        chain_into(drv.rx_buf(p).addr, rx_share[p], sg_chunk, &mut descs);
        sys.cpu_exec(Dur(descs.len() as u64 * sys.cfg.kernel_desc_build_ns));
        sys.program_dma_slice_on(EngineId(p as u8), Channel::S2mm, DmaMode::ScatterGather, &descs);
    }
    sys.put_desc_scratch(descs);

    // Pipelined TX feed, round-robin across engines.
    let mut off = 0u64;
    let mut i = 0usize;
    let mut fed = vec![0usize; n];
    let mut programmed = vec![false; n];
    while off < tx_bytes {
        let len = sg_chunk.min(tx_bytes - off);
        let p = i % n;
        if !zero_copy {
            sys.cpu_copy(len, CopyKind::KernelCached);
            let fl = flush_time(sys, len);
            sys.cpu_exec(fl);
        }
        sys.cpu_exec(Dur(sys.cfg.kernel_desc_build_ns));
        let mut d = Descriptor::new(drv.tx_buf(i).addr, len);
        if fed[p] + 1 == chunks_of[p] {
            // Last chunk of this engine's stripe: interrupt on complete.
            d = d.with_irq();
        }
        if !programmed[p] {
            sys.program_dma_slice_on(EngineId(p as u8), Channel::Mm2s, DmaMode::ScatterGather, &[d]);
            programmed[p] = true;
        } else {
            sys.append_dma_slice_on(EngineId(p as u8), Channel::Mm2s, &[d]);
        }
        fed[p] += 1;
        off += len;
        i += 1;
    }

    // Collect every TX completion, then every RX completion.
    let mut rescues = 0u32;
    let mut recovery_ns = 0u64;
    for p in 0..n {
        if tx_share[p] > 0 {
            mq_wait(sys, EngineId(p as u8), Channel::Mm2s, &mut rescues, &mut recovery_ns)?;
        }
    }
    let tx_time = sys.now().since(t0);

    let rx_time = if rx_bytes > 0 {
        for p in 0..n {
            if rx_share[p] == 0 {
                continue;
            }
            mq_wait(sys, EngineId(p as u8), Channel::S2mm, &mut rescues, &mut recovery_ns)?;
            if !zero_copy {
                let mut left = rx_share[p];
                while left > 0 {
                    let len = sg_chunk.min(left);
                    let fl = flush_time(sys, len);
                    sys.cpu_exec(fl); // dma_unmap invalidate
                    sys.cpu_copy(len, CopyKind::KernelCached);
                    left -= len;
                }
            }
        }
        if zero_copy {
            // One dma_unmap of the whole frame; software reads in place.
            sys.coherency_rx(rx_bytes);
        }
        let exit = sys.costs.syscall_exit();
        sys.cpu_exec(exit);
        sys.now().since(t0)
    } else {
        let exit = sys.costs.syscall_exit();
        sys.cpu_exec(exit);
        Dur::ZERO
    };

    let outcome = if rescues == 0 {
        TransferOutcome::Completed
    } else {
        TransferOutcome::Recovered { retries: rescues, recovery_ns }
    };
    Ok(TransferReport { tx_bytes, rx_bytes, tx_time, rx_time, ledger: CpuLedger::default(), outcome })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;
    use crate::drivers::{Driver, DriverConfig, DriverKind};
    use crate::memory::buffer::CmaAllocator;

    fn run_cfg(bytes: u64, dcfg: DriverConfig) -> (TransferReport, System) {
        let sys_cfg = SimConfig::default();
        let mut sys = System::loopback(sys_cfg.clone());
        let mut cma = CmaAllocator::zynq_default();
        let mut drv = Driver::new(dcfg, &mut cma, &sys_cfg, bytes).unwrap();
        let r = drv.transfer(&mut sys, bytes, bytes).unwrap();
        (r, sys)
    }

    fn pipelined() -> DriverConfig {
        DriverConfig {
            kind: DriverKind::KernelIrq,
            buffering: BufferScheme::Double,
            partition: PartitionMode::Blocks,
        }
    }

    fn run(bytes: u64) -> (TransferReport, System) {
        run_cfg(bytes, pipelined())
    }

    #[test]
    fn small_transfer_dominated_by_fixed_costs() {
        let (r, _) = run(64);
        // Fixed path: ioctl + submit + desc builds + 2 IRQ paths — tens
        // of microseconds regardless of payload.
        assert!(r.rx_time.as_us() > 10.0, "fixed overhead missing: {}", r.rx_time);
    }

    #[test]
    fn uses_scatter_gather_chunks() {
        let (_, sys) = run(1 << 20);
        let chunks = (1u64 << 20).div_ceil(SimConfig::default().kernel_sg_chunk_bytes);
        assert_eq!(sys.mm2s().stats.desc_fetches, chunks);
        assert!(sys.s2mm().stats.desc_fetches >= chunks);
    }

    #[test]
    fn waits_are_interrupt_driven_not_polled() {
        let (r, _) = run(1 << 20);
        assert_eq!(r.ledger.poll_reads, 0);
        assert_eq!(r.ledger.irqs, 2);
        assert!(r.ledger.freed > Dur::ZERO);
    }

    #[test]
    fn pipelining_beats_copy_then_dma() {
        // The pipelined shape must beat the Table-I worst case for a
        // payload much larger than one SG chunk.
        let bytes = 4 << 20;
        let (fast, _) = run_cfg(bytes, pipelined());
        let (slow, _) = run_cfg(bytes, DriverConfig::table1(DriverKind::KernelIrq));
        assert!(
            fast.rx_time < slow.rx_time,
            "pipelined {} not faster than worst case {}",
            fast.rx_time,
            slow.rx_time
        );
    }

    #[test]
    fn worst_case_serialises_copy_before_dma() {
        // In worst-case mode the TX copy+flush happens before the engine
        // starts: TX time must exceed copy+flush+stream serially.
        let bytes = 2 << 20;
        let (r, sys) = run_cfg(bytes, DriverConfig::table1(DriverKind::KernelIrq));
        let copy = sys.copy.copy_time(bytes, CopyKind::KernelCached, false);
        let flush = Dur::for_bytes(bytes, sys.cfg.kernel_cache_flush_bps);
        let stream = Dur::for_bytes(bytes, sys.cfg.stream_bandwidth_bps);
        assert!(
            r.tx_time.ns() >= copy.ns() + flush.ns() + stream.ns(),
            "tx {} < serial floor {}",
            r.tx_time,
            copy + flush + stream
        );
    }

    #[test]
    fn kernel_split_phase_equals_blocking() {
        let bytes = 1 << 20;
        let (blocking, _) = run_cfg(bytes, DriverConfig::table1(DriverKind::KernelIrq));
        let sys_cfg = SimConfig::default();
        let mut sys = System::loopback(sys_cfg.clone());
        let mut cma = CmaAllocator::zynq_default();
        let dcfg = DriverConfig::table1(DriverKind::KernelIrq);
        let mut drv = Driver::new(dcfg, &mut cma, &sys_cfg, bytes).unwrap();
        let tok = drv.submit(&mut sys, bytes, bytes).unwrap();
        let split = drv.complete(&mut sys, tok).unwrap();
        assert_eq!(split.tx_time, blocking.tx_time);
        assert_eq!(split.rx_time, blocking.rx_time);
    }

    #[test]
    fn multiqueue_stripes_sum_to_payload() {
        let mut sys_cfg = SimConfig::default();
        sys_cfg.num_engines = 3;
        let mut sys = System::loopback(sys_cfg.clone());
        let mut cma = CmaAllocator::zynq_default();
        let dcfg = DriverConfig::table1(DriverKind::KernelMultiQueue);
        let bytes = 1 << 20;
        let mut drv = Driver::new(dcfg, &mut cma, &sys_cfg, bytes).unwrap();
        let r = drv.transfer(&mut sys, bytes, bytes).unwrap();
        assert_eq!(r.tx_bytes, bytes);
        let tx_total: u64 = (0..3).map(|p| sys.port(EngineId(p)).mm2s.stats.bytes).sum();
        let rx_total: u64 = (0..3).map(|p| sys.port(EngineId(p)).s2mm.stats.bytes).sum();
        assert_eq!(tx_total, bytes);
        assert_eq!(rx_total, bytes);
    }

    #[test]
    fn multiqueue_on_one_engine_matches_pipelined_shape() {
        // With a single engine the multi-queue scheme degenerates to the
        // pipelined SG feed; the IRQ count must stay at 2 (TX + RX).
        let sys_cfg = SimConfig::default();
        let mut sys = System::loopback(sys_cfg.clone());
        let mut cma = CmaAllocator::zynq_default();
        let dcfg = DriverConfig::table1(DriverKind::KernelMultiQueue);
        let mut drv = Driver::new(dcfg, &mut cma, &sys_cfg, 1 << 20).unwrap();
        let r = drv.transfer(&mut sys, 1 << 20, 1 << 20).unwrap();
        assert_eq!(r.ledger.irqs, 2);
        assert_eq!(r.ledger.poll_reads, 0);
    }
}
