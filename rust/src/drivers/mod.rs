//! The paper's transfer-management schemes: who moves data between the
//! application's virtual memory and the DMA-visible physical bounce
//! buffers, and how completion is awaited.
//!
//! Three **drivers** (§III), each a [`TransferScheme`] implementation:
//!
//! * [`DriverKind::UserPolling`] — `mmap()`'d registers + CMA buffer,
//!   spin on the status register. Lowest latency, burns the CPU, no
//!   memory protection, can deadlock the system on unbalanced TX/RX.
//! * [`DriverKind::UserScheduled`] — same user-space register access but
//!   the wait usleeps, letting the OS schedule other tasks.
//! * [`DriverKind::KernelIrq`] — ioctl into a kernel driver wrapping the
//!   Xilinx AXI-DMA dmaengine: `copy_{from,to}_user` through cached
//!   kernel mappings, scatter-gather descriptor chains pipelined with the
//!   copies, interrupt-driven completion.
//!
//! Plus one post-paper scheme that exists because the system now models
//! multiple AXI-DMA engines:
//!
//! * [`DriverKind::KernelMultiQueue`] — a kernel driver that stripes one
//!   payload's SG chunks round-robin across *every* engine's queues
//!   (NEURAghe-style multi-port exploitation) and waits on all completion
//!   interrupts.
//!
//! Two orthogonal knobs for the user-level drivers (§III.A):
//!
//! * [`BufferScheme`] — `Single` reuses one bounce buffer (next chunk's
//!   copy must wait for the engine); `Double` ping-pongs two, overlapping
//!   the copy of chunk *i+1* with the DMA of chunk *i*.
//! * [`PartitionMode`] — `Unique` sends the whole payload as one
//!   transfer; `Blocks` chops it into `blocks_chunk_bytes` pieces so
//!   double buffering has something to overlap.
//!
//! Every combination exposes the same entry point,
//! [`Driver::transfer`], which runs one TX/RX round trip on a
//! [`System`] and reports software-observed TX/RX completion times plus
//! the CPU ledger. The frame-pipelined coordinator instead uses the
//! split-phase [`Driver::submit`] / [`Driver::complete`] pair so several
//! frames can be in flight on different engines at once.

pub mod kernel;
pub mod scheme;
pub mod user;

pub use scheme::{scheme_for, SubmitToken, TransferScheme};

use crate::axi::descriptor::MAX_DESC_LEN;
use crate::memory::buffer::{AllocError, CmaAllocator, DmaBuffer};
use crate::obs::{Ctr, HistId};
use crate::sim::event::EngineId;
use crate::sim::fault::DmaErrorKind;
use crate::sim::time::Dur;
use crate::system::{CpuLedger, SimError, System};

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum DriverKind {
    UserPolling,
    UserScheduled,
    KernelIrq,
    /// Kernel SG driver striping chunks across every DMA engine.
    KernelMultiQueue,
}

impl DriverKind {
    /// Paper row/series label.
    pub fn label(self) -> &'static str {
        match self {
            DriverKind::UserPolling => "user-level polling",
            DriverKind::UserScheduled => "user-level drv scheduled",
            DriverKind::KernelIrq => "kernel-level drv",
            DriverKind::KernelMultiQueue => "kernel-level multi-queue",
        }
    }

    /// The paper's three measured schemes (the multi-queue scheme is a
    /// post-paper extension and is exercised by the scaling experiments).
    pub const ALL: [DriverKind; 3] =
        [DriverKind::UserPolling, DriverKind::UserScheduled, DriverKind::KernelIrq];

    /// Parse a CLI/config spelling (`serve --driver <s>`). Accepts the
    /// short forms and the hyphenated full labels.
    pub fn parse(s: &str) -> Option<DriverKind> {
        match s {
            "polling" | "user-polling" => Some(DriverKind::UserPolling),
            "scheduled" | "user-scheduled" => Some(DriverKind::UserScheduled),
            "kernel" | "kernel-irq" => Some(DriverKind::KernelIrq),
            "multiqueue" | "kernel-multiqueue" => Some(DriverKind::KernelMultiQueue),
            _ => None,
        }
    }
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum BufferScheme {
    Single,
    Double,
}

#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum PartitionMode {
    /// One transfer for the whole payload.
    Unique,
    /// Chunked into `blocks_chunk_bytes` transfers.
    Blocks,
}

/// Full driver configuration for one experiment cell.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct DriverConfig {
    pub kind: DriverKind,
    pub buffering: BufferScheme,
    pub partition: PartitionMode,
}

impl DriverConfig {
    /// The paper's Table I configuration: "single-buffer" + "Unique".
    pub fn table1(kind: DriverKind) -> DriverConfig {
        DriverConfig { kind, buffering: BufferScheme::Single, partition: PartitionMode::Unique }
    }
}

/// What a transfer attempt can report.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum DriverError {
    Sim(SimError),
    Alloc(AllocError),
    TooLarge { bytes: u64 },
    /// The transfer failed under fault injection: recovery was exhausted
    /// (`retries` attempts) or impossible. `kind` is the last latched
    /// DMA error, or `None` when the failure was a bare wait timeout.
    Faulted { ch: &'static str, retries: u32, kind: Option<DmaErrorKind> },
}

impl std::fmt::Display for DriverError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            DriverError::Sim(e) => e.fmt(f),
            DriverError::Alloc(e) => write!(f, "CMA allocation failed: {e}"),
            DriverError::TooLarge { bytes } => write!(
                f,
                "transfer of {bytes} bytes exceeds the user-level 8 MB AXI-DMA limit \
                 ({MAX_DESC_LEN} bytes per descriptor) in Unique mode"
            ),
            DriverError::Faulted { ch, retries, kind } => match kind {
                Some(k) => write!(
                    f,
                    "{ch} transfer failed after {retries} recovery attempt(s): {}",
                    k.label()
                ),
                None => write!(
                    f,
                    "{ch} transfer failed after {retries} recovery attempt(s): wait timeout"
                ),
            },
        }
    }
}

impl std::error::Error for DriverError {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            // Transparent: Display already *is* the inner error, so
            // exposing it again as a source would print it twice in
            // error-chain walkers.
            DriverError::Sim(_) => None,
            DriverError::Alloc(e) => Some(e),
            DriverError::TooLarge { .. } => None,
            DriverError::Faulted { .. } => None,
        }
    }
}

impl From<SimError> for DriverError {
    fn from(e: SimError) -> Self {
        DriverError::Sim(e)
    }
}

impl From<AllocError> for DriverError {
    fn from(e: AllocError) -> Self {
        DriverError::Alloc(e)
    }
}

/// How a *successful* transfer concluded with respect to fault
/// injection. The third leg of the outcome space — recovery exhausted,
/// payload dropped — is [`DriverError::Faulted`], which the
/// coordinator's reliability sweep tallies as `FaultCell::failed`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum TransferOutcome {
    /// No fault touched this transfer.
    Completed,
    /// Faults were detected and recovered: `retries` reset/re-arm (or
    /// watchdog-rescue) rounds, `recovery_ns` spent inside recovery
    /// actions (the reliability sweep's recovery-latency metric).
    Recovered { retries: u32, recovery_ns: u64 },
}

/// Software-observed timing of one TX/RX round trip. All durations are
/// measured from the instant the application handed the payload to the
/// driver (t0), matching the paper's instrumentation.
#[derive(Clone, Copy, Debug)]
pub struct TransferReport {
    pub tx_bytes: u64,
    pub rx_bytes: u64,
    /// t0 → software observes TX (MM2S) complete, including the TX-side
    /// staging copy.
    pub tx_time: Dur,
    /// t0 → RX payload available in application virtual memory (S2MM
    /// complete + copy-back).
    pub rx_time: Dur,
    /// CPU accounting over the transfer window.
    pub ledger: CpuLedger,
    /// Fault/recovery story of this transfer (always `Completed` when
    /// the fault plan is inactive).
    pub outcome: TransferOutcome,
}

impl TransferReport {
    pub fn tx_us_per_byte(&self) -> f64 {
        self.tx_time.as_us() / self.tx_bytes.max(1) as f64
    }

    pub fn rx_us_per_byte(&self) -> f64 {
        self.rx_time.as_us() / self.rx_bytes.max(1) as f64
    }
}

/// Bounce-buffer set held by a driver instance (allocated once, reused
/// across transfers, as a real application would).
struct BounceBufs {
    tx: Vec<DmaBuffer>,
    rx: Vec<DmaBuffer>,
}

/// One configured driver bound to a CMA reservation and one DMA engine
/// (the multi-queue scheme additionally touches every other engine).
pub struct Driver {
    pub cfg: DriverConfig,
    /// The engine this driver programs and waits on.
    pub port: EngineId,
    bufs: BounceBufs,
    /// Capacity of each bounce buffer.
    buf_len: u64,
    /// Zero-copy fast path: the `(tx_bytes, rx_bytes)` the cyclic SG
    /// rings are currently armed for, if any. The first frame arms the
    /// rings (full program cost); later frames of the same shape only
    /// pay a doorbell trigger. Cleared by fault recovery and by a shape
    /// change, both of which force a re-arm.
    pub(crate) armed: Option<(u64, u64)>,
    /// TX byte count already staged into the bounce buffer by
    /// [`Driver::prestage`], consumed by the next split-phase submit of
    /// the same size (which then skips its own staging copy).
    pub(crate) prestaged: Option<u64>,
}

impl Driver {
    /// Set up bounce buffers sized for transfers up to `max_bytes`, bound
    /// to engine 0.
    pub fn new(
        cfg: DriverConfig,
        cma: &mut CmaAllocator,
        sys_cfg: &crate::config::SimConfig,
        max_bytes: u64,
    ) -> Result<Driver, DriverError> {
        Driver::new_on(cfg, cma, sys_cfg, max_bytes, EngineId::ZERO)
    }

    /// Set up bounce buffers sized for transfers up to `max_bytes`, bound
    /// to engine `port`.
    ///
    /// * user Unique: full-payload buffers (1 or 2 per direction);
    /// * user Blocks: chunk-sized buffers (1 or 2 per direction);
    /// * kernel: two SG-chunk bounce buffers per direction (the driver's
    ///   internal pipeline), regardless of the user-visible knobs;
    /// * zero-copy (any driver): one full-payload in-place region per
    ///   direction — frames are produced/consumed directly in it, so
    ///   there is nothing to ping-pong and no staging to chunk.
    pub fn new_on(
        cfg: DriverConfig,
        cma: &mut CmaAllocator,
        sys_cfg: &crate::config::SimConfig,
        max_bytes: u64,
        port: EngineId,
    ) -> Result<Driver, DriverError> {
        let zero_copy = sys_cfg.memory.is_zero_copy();
        let kernel_worst_case = cfg.kind == DriverKind::KernelIrq
            && cfg.buffering == BufferScheme::Single
            && cfg.partition == PartitionMode::Unique;
        let buf_len = match (cfg.kind, cfg.partition) {
            _ if zero_copy => max_bytes,
            // Worst-case kernel mode stages the whole payload at once.
            (DriverKind::KernelIrq, _) if kernel_worst_case => max_bytes,
            (DriverKind::KernelIrq, _) | (DriverKind::KernelMultiQueue, _) => {
                sys_cfg.kernel_sg_chunk_bytes
            }
            (_, PartitionMode::Unique) => max_bytes,
            (_, PartitionMode::Blocks) => sys_cfg.blocks_chunk_bytes.min(max_bytes),
        };
        let n = match (cfg.kind, cfg.buffering) {
            _ if zero_copy => 1,
            (DriverKind::KernelIrq | DriverKind::KernelMultiQueue, _) => 2,
            (_, BufferScheme::Single) => 1,
            (_, BufferScheme::Double) => 2,
        };
        let mut tx = Vec::new();
        let mut rx = Vec::new();
        for _ in 0..n {
            tx.push(cma.alloc(buf_len)?);
            rx.push(cma.alloc(buf_len)?);
        }
        Ok(Driver {
            cfg,
            port,
            bufs: BounceBufs { tx, rx },
            buf_len,
            armed: None,
            prestaged: None,
        })
    }

    /// Release the bounce buffers back to the CMA pool.
    pub fn release(self, cma: &mut CmaAllocator) {
        for b in self.bufs.tx.into_iter().chain(self.bufs.rx) {
            cma.free(b).expect("driver buffers double-freed");
        }
    }

    pub fn buf_len(&self) -> u64 {
        self.buf_len
    }

    pub(crate) fn tx_buf(&self, i: usize) -> DmaBuffer {
        self.bufs.tx[i % self.bufs.tx.len()]
    }

    pub(crate) fn rx_buf(&self, i: usize) -> DmaBuffer {
        self.bufs.rx[i % self.bufs.rx.len()]
    }

    /// Run one TX/RX round trip: send `tx_bytes` to the PL, receive
    /// `rx_bytes` back (loop-back: equal; NullHop layer: rx is the output
    /// feature map). The PL device must already be set up to consume/
    /// produce these amounts. Dispatches through this driver's
    /// [`TransferScheme`].
    pub fn transfer(
        &mut self,
        sys: &mut System,
        tx_bytes: u64,
        rx_bytes: u64,
    ) -> Result<TransferReport, DriverError> {
        assert!(tx_bytes > 0, "transfer with no TX payload");
        let ledger_before = sys.ledger;
        let mut report = scheme_for(self.cfg.kind).transfer(self, sys, tx_bytes, rx_bytes)?;
        report.ledger = diff_ledger(ledger_before, sys.ledger);
        self.record_obs(sys, &report);
        Ok(report)
    }

    /// Record one finished round trip into the per-scheme telemetry
    /// lane. Pure observation: only the already-built report is read.
    fn record_obs(&self, sys: &mut System, r: &TransferReport) {
        if !sys.obs.enabled() {
            return;
        }
        let k = self.cfg.kind;
        sys.obs.add(Ctr::tx_bytes(k), r.tx_bytes);
        sys.obs.add(Ctr::rx_bytes(k), r.rx_bytes);
        sys.obs.inc(Ctr::transfers(k));
        if let TransferOutcome::Recovered { retries, .. } = r.outcome {
            sys.obs.add(Ctr::retries(k), retries as u64);
        }
        sys.obs.observe(HistId::TxWindowNs, r.tx_time.ns());
        sys.obs.observe(HistId::RxWindowNs, r.rx_time.ns());
    }

    /// Split-phase entry: stage + arm one TX/RX round trip on this
    /// driver's engine *without waiting*. Pair with [`Driver::complete`].
    /// Used by the frame-pipelined coordinator to keep several frames in
    /// flight; always Unique-shaped (one arm per direction).
    pub fn submit(
        &mut self,
        sys: &mut System,
        tx_bytes: u64,
        rx_bytes: u64,
    ) -> Result<SubmitToken, DriverError> {
        assert!(tx_bytes > 0, "submit with no TX payload");
        scheme_for(self.cfg.kind).submit(self, sys, tx_bytes, rx_bytes)
    }

    /// Software double-buffering of the *next* transfer's staging copy:
    /// stage `tx_bytes` into the TX bounce buffer now, so the next
    /// split-phase [`Driver::submit`] of the same size skips its copy.
    ///
    /// Called between `submit(N)` and `complete(N)` — the copy's CPU
    /// time then runs while the engine drains frame N, which is exactly
    /// the overlap the §III.A double-buffer scheme buys *within* one
    /// payload, lifted to adjacent layers. Only the user-level
    /// copy-through drivers have a staging copy to hide: the kernel
    /// driver copies inside the syscall (unobservable from here) and
    /// zero-copy paths have no staging copy at all, so for those this is
    /// a no-op. Returns whether a copy was actually performed.
    pub fn prestage(&mut self, sys: &mut System, tx_bytes: u64) -> bool {
        let copy_through = matches!(
            self.cfg.kind,
            DriverKind::UserPolling | DriverKind::UserScheduled
        );
        if !copy_through || sys.cfg.memory.is_zero_copy() || tx_bytes == 0 {
            return false;
        }
        sys.cpu_copy(tx_bytes, crate::memory::copy::CopyKind::UserUncached);
        self.prestaged = Some(tx_bytes);
        sys.obs.inc(Ctr::DrvPrestages);
        true
    }

    /// Split-phase completion: wait for both directions of a prior
    /// [`Driver::submit`] and copy the RX payload out.
    pub fn complete(
        &mut self,
        sys: &mut System,
        token: SubmitToken,
    ) -> Result<TransferReport, DriverError> {
        let report = scheme_for(self.cfg.kind).complete(self, sys, token)?;
        self.record_obs(sys, &report);
        Ok(report)
    }
}

/// Ledger delta `after − before` (`pub(crate)`: the serve loop reports
/// the same six-field delta over a whole run).
pub(crate) fn diff_ledger(before: CpuLedger, after: CpuLedger) -> CpuLedger {
    CpuLedger {
        busy: after.busy.saturating_sub(before.busy),
        freed: after.freed.saturating_sub(before.freed),
        used_by_tasks: after.used_by_tasks.saturating_sub(before.used_by_tasks),
        poll_reads: after.poll_reads - before.poll_reads,
        sleep_cycles: after.sleep_cycles - before.sleep_cycles,
        irqs: after.irqs - before.irqs,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::SimConfig;

    fn setup(cfg: DriverConfig, max: u64) -> (System, CmaAllocator, Driver) {
        let sys_cfg = SimConfig::default();
        let sys = System::loopback(sys_cfg.clone());
        let mut cma = CmaAllocator::zynq_default();
        let drv = Driver::new(cfg, &mut cma, &sys_cfg, max).unwrap();
        (sys, cma, drv)
    }

    #[test]
    fn all_nine_user_cells_complete_a_loopback() {
        for kind in [DriverKind::UserPolling, DriverKind::UserScheduled] {
            for buffering in [BufferScheme::Single, BufferScheme::Double] {
                for partition in [PartitionMode::Unique, PartitionMode::Blocks] {
                    let cfg = DriverConfig { kind, buffering, partition };
                    let (mut sys, mut cma, mut drv) = setup(cfg, 1 << 20);
                    let r = drv.transfer(&mut sys, 1 << 20, 1 << 20).unwrap();
                    assert!(r.tx_time > Dur::ZERO, "{cfg:?}");
                    assert!(r.rx_time >= r.tx_time, "{cfg:?}");
                    drv.release(&mut cma);
                    assert_eq!(cma.free_bytes(), cma.capacity());
                }
            }
        }
    }

    #[test]
    fn kernel_cell_completes_a_loopback() {
        let cfg = DriverConfig::table1(DriverKind::KernelIrq);
        let (mut sys, _cma, mut drv) = setup(cfg, 1 << 20);
        let r = drv.transfer(&mut sys, 1 << 20, 1 << 20).unwrap();
        assert!(r.rx_time >= r.tx_time);
        assert!(r.ledger.irqs >= 2, "kernel driver is interrupt-driven");
    }

    #[test]
    fn user_unique_rejects_past_8mb_limit() {
        let cfg = DriverConfig::table1(DriverKind::UserPolling);
        let (mut sys, _cma, mut drv) = setup(cfg, 16 << 20);
        let err = drv.transfer(&mut sys, 9 << 20, 9 << 20).unwrap_err();
        assert!(matches!(err, DriverError::TooLarge { .. }));
    }

    #[test]
    fn kernel_sg_handles_past_8mb() {
        let cfg = DriverConfig::table1(DriverKind::KernelIrq);
        let (mut sys, _cma, mut drv) = setup(cfg, 16 << 20);
        let r = drv.transfer(&mut sys, 9 << 20, 9 << 20).unwrap();
        assert_eq!(r.tx_bytes, 9 << 20);
    }

    #[test]
    fn prestage_moves_the_staging_copy_out_of_submit() {
        let cfg = DriverConfig::table1(DriverKind::UserPolling);
        let submit_time = |prestage: Option<u64>| {
            let (mut sys, mut cma, mut drv) = setup(cfg, 1 << 20);
            if let Some(b) = prestage {
                assert!(drv.prestage(&mut sys, b));
            }
            let t0 = sys.now();
            let tok = drv.submit(&mut sys, 1 << 20, 1 << 20).unwrap();
            let dt = sys.now().since(t0);
            drv.complete(&mut sys, tok).unwrap();
            drv.release(&mut cma);
            dt
        };
        let plain = submit_time(None);
        let prestaged = submit_time(Some(1 << 20));
        assert!(prestaged < plain, "prestaged submit must skip its copy");
        // A stale prestage of the wrong size is discarded, not reused.
        let stale = submit_time(Some(1 << 10));
        assert_eq!(stale, plain);
        // Kernel drivers copy inside the syscall: nothing to prestage.
        let (mut sys, _cma, mut drv) =
            setup(DriverConfig::table1(DriverKind::KernelIrq), 1 << 20);
        assert!(!drv.prestage(&mut sys, 1 << 20));
    }

    #[test]
    fn parse_accepts_short_and_full_labels() {
        assert_eq!(DriverKind::parse("polling"), Some(DriverKind::UserPolling));
        assert_eq!(DriverKind::parse("user-scheduled"), Some(DriverKind::UserScheduled));
        assert_eq!(DriverKind::parse("kernel"), Some(DriverKind::KernelIrq));
        assert_eq!(DriverKind::parse("multiqueue"), Some(DriverKind::KernelMultiQueue));
        assert_eq!(DriverKind::parse("dpdk"), None);
    }

    #[test]
    fn per_byte_helpers() {
        let r = TransferReport {
            tx_bytes: 1000,
            rx_bytes: 500,
            tx_time: Dur::from_us(10.0),
            rx_time: Dur::from_us(20.0),
            ledger: CpuLedger::default(),
            outcome: TransferOutcome::Completed,
        };
        assert!((r.tx_us_per_byte() - 0.01).abs() < 1e-12);
        assert!((r.rx_us_per_byte() - 0.04).abs() < 1e-12);
    }

    #[test]
    fn multiqueue_completes_and_uses_every_engine() {
        let mut sys_cfg = SimConfig::default();
        sys_cfg.num_engines = 2;
        let mut sys = System::loopback(sys_cfg.clone());
        let mut cma = CmaAllocator::zynq_default();
        let cfg = DriverConfig::table1(DriverKind::KernelMultiQueue);
        let mut drv = Driver::new(cfg, &mut cma, &sys_cfg, 2 << 20).unwrap();
        let r = drv.transfer(&mut sys, 2 << 20, 2 << 20).unwrap();
        assert_eq!(r.tx_bytes, 2 << 20);
        assert!(sys.port(EngineId(0)).mm2s.stats.bytes > 0);
        assert!(sys.port(EngineId(1)).mm2s.stats.bytes > 0);
        assert_eq!(
            sys.port(EngineId(0)).mm2s.stats.bytes + sys.port(EngineId(1)).mm2s.stats.bytes,
            2 << 20
        );
    }

    #[test]
    fn multiqueue_on_two_engines_beats_single_engine_kernel() {
        // Striping only pays when the per-engine stream, not the CPU's
        // copy+flush feed, is the bottleneck — so run a DMA-bound config
        // (fast copies/flushes, paper-default 400 MB/s streams).
        let bytes = 4 << 20;
        let run = |engines: u64, kind: DriverKind| {
            let mut sys_cfg = SimConfig::default();
            sys_cfg.num_engines = engines;
            sys_cfg.kernel_cache_flush_bps = 4e9;
            sys_cfg.memcpy_bw_cached_bps = 8e9;
            sys_cfg.memcpy_bw_ddr_bps = 8e9;
            let mut sys = System::loopback(sys_cfg.clone());
            let mut cma = CmaAllocator::zynq_default();
            let dcfg = DriverConfig {
                kind,
                buffering: BufferScheme::Double,
                partition: PartitionMode::Blocks,
            };
            let mut drv = Driver::new(dcfg, &mut cma, &sys_cfg, bytes).unwrap();
            drv.transfer(&mut sys, bytes, bytes).unwrap().rx_time
        };
        let single = run(1, DriverKind::KernelIrq);
        let multi = run(2, DriverKind::KernelMultiQueue);
        assert!(multi < single, "striping across 2 engines must beat one: {multi} !< {single}");
    }
}
