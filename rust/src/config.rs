//! Simulator configuration: every timing constant of the modelled Zynq-7100
//! MMP platform in one place.
//!
//! The defaults are **calibrated** against the paper's own published
//! numbers (Table I anchors, Fig. 4/5 crossover behaviour) plus public
//! Zynq-7000-series datasheet figures (AXI HP port width/clock, ARM A9
//! Linux syscall/context-switch costs). DESIGN.md §6 lists the anchors.
//! Every field can be overridden from a JSON file via [`SimConfig::load`],
//! which is how the calibration harness sweeps constants.

use std::path::Path;

use crate::cluster::ClusterConfig;
use crate::coordinator::model::ModelConfig;
use crate::memory::path::MemoryConfig;
use crate::obs::ObsConfig;
use crate::sim::engine::CalendarKind;
use crate::sim::fault::FaultConfig;
use crate::util::json::Json;
use crate::workload::WorkloadConfig;

/// All model constants. Units are in the field names: `_ns` = nanoseconds,
/// `_bps` = bytes/second, `_bytes` = bytes, `_hz` = Hertz.
#[derive(Clone, Debug, PartialEq)]
pub struct SimConfig {
    // ---- DDR controller / PS memory system ------------------------------
    /// Effective DDR3 bandwidth seen by one AXI HP port (64-bit @ 150 MHz,
    /// derated for refresh + arbitration).
    pub ddr_bandwidth_bps: f64,
    /// Fixed latency per burst: HP-port arbitration + controller queue +
    /// CAS. Paid once per DDR burst.
    pub ddr_latency_ns: u64,
    /// Extra penalty when the controller switches between read and write
    /// streams (bus turnaround). This is what makes concurrent TX/RX slower
    /// than either alone — the paper's "DDR memory cannot attend read and
    /// write operations at the same time".
    pub ddr_turnaround_ns: u64,
    /// Per-engine DDR arbitration weights (deficit round-robin within
    /// each priority class): engine `i` gets `weights[i]` grants per
    /// refill round; engines beyond the list inherit the last entry, so
    /// `[1]` means "all equal". See DESIGN.md §7.
    pub ddr_engine_weights: Vec<u64>,

    // ---- Multi-engine topology ------------------------------------------
    /// Number of independent AXI-DMA engines (MM2S/S2MM pairs with their
    /// own FIFOs, register blocks, IRQ lines and PL device instance).
    /// The paper's platform is `1`; NEURAghe-style multi-port scaling
    /// experiments sweep this up to [`crate::sim::event::MAX_ENGINES`].
    pub num_engines: u64,

    // ---- AXI interconnect / DMA engine ----------------------------------
    /// AXI4-Stream payload bandwidth between DMA and PL (64-bit @ 100 MHz).
    pub stream_bandwidth_bps: f64,
    /// Largest single AXI burst the DMA issues (256 beats x 8 B).
    pub max_burst_bytes: u64,
    /// Datamover FIFO between MM2S and the PL device.
    pub mm2s_fifo_bytes: u64,
    /// Datamover FIFO between the PL device and S2MM.
    pub s2mm_fifo_bytes: u64,
    /// Cost of one scatter-gather descriptor fetch from DDR.
    pub desc_fetch_ns: u64,
    /// Uncached register write from the PS into the DMA (via M_AXI_GP).
    pub reg_write_ns: u64,
    /// Uncached register read (status polling). Slightly slower than a
    /// write because the A9 stalls on the read response.
    pub reg_read_ns: u64,

    // ---- CPU / memcpy model ----------------------------------------------
    /// memcpy bandwidth when the working set fits in the A9's L2 (cached,
    /// store-buffer friendly).
    pub memcpy_bw_cached_bps: f64,
    /// memcpy bandwidth DDR-to-DDR (both sides miss; A9 @ 666 MHz).
    pub memcpy_bw_ddr_bps: f64,
    /// Working-set size above which memcpy degrades to DDR bandwidth
    /// (the Zynq A9 L2 is 512 KB shared; half is a realistic usable set).
    pub memcpy_cache_threshold_bytes: u64,
    /// Multiplier (<1) applied to memcpy bandwidth while a DMA transfer is
    /// in flight — the copy and the engine contend for the same DDR.
    pub memcpy_dma_contention: f64,
    /// User-level bounce buffers are mapped non-cacheable (CMA via
    /// /dev/mem): stores cannot hit the cache, costing extra per byte.
    pub uncached_copy_factor: f64,

    // ---- OS model ---------------------------------------------------------
    /// One-way user->kernel mode switch (trap + register save).
    pub syscall_entry_ns: u64,
    /// Kernel->user return path.
    pub syscall_exit_ns: u64,
    /// Full context switch between tasks (save/restore + scheduler pick +
    /// cache/TLB disturbance amortised in).
    pub ctx_switch_ns: u64,
    /// GIC distributor latency from peripheral edge to CPU IRQ assertion.
    pub gic_latency_ns: u64,
    /// IRQ entry: pipeline flush, vector, handler prologue.
    pub isr_entry_ns: u64,
    /// The AXI-DMA ISR body (ack IRQ, walk completed descriptors).
    pub isr_dma_handler_ns: u64,
    /// Waking a blocked task from the ISR bottom half (softirq + enqueue).
    pub wake_latency_ns: u64,
    /// Round-robin timeslice of the modelled CFS (only matters when
    /// background load is enabled).
    pub timeslice_ns: u64,
    /// Re-check period of the *scheduled* user-level driver: instead of
    /// spinning it sleeps this long between status reads (usleep-based).
    pub sched_poll_period_ns: u64,

    // ---- Driver constants --------------------------------------------------
    /// User-level: computing register values / bookkeeping per transfer.
    pub user_setup_ns: u64,
    /// Extra CPU overhead in the polling loop per status read (loop body,
    /// barrier).
    pub poll_loop_overhead_ns: u64,
    /// Slowdown factor (>1) on DMA service while the CPU is actively
    /// spinning on the status register: the uncached reads occupy the same
    /// interconnect the engine uses for descriptor/status traffic.
    pub polling_dma_penalty: f64,
    /// Kernel driver: ioctl argument marshalling + dmaengine submit path.
    pub kernel_submit_ns: u64,
    /// Kernel driver: building one SG descriptor (alloc from pool + fill).
    pub kernel_desc_build_ns: u64,
    /// Kernel driver: granularity of the copy_{from,to}_user pipeline. The
    /// driver copies one chunk while the engine DMAs the previous one.
    pub kernel_sg_chunk_bytes: u64,
    /// Cache clean (TX) / invalidate (RX) throughput for dma_map_single on
    /// the kernel bounce buffers: the A9 walks the lines by MVA. This is
    /// the per-byte toll that makes the kernel path *slower per byte* than
    /// the user drivers in Table I despite its cached copies.
    pub kernel_cache_flush_bps: f64,
    /// Default chunk size of the user-level *Blocks* mode.
    pub blocks_chunk_bytes: u64,

    // ---- PL devices --------------------------------------------------------
    /// Loop-back core: pipeline latency input beat -> output beat.
    pub loopback_latency_ns: u64,
    /// Loop-back core internal FIFO (bounds TX/RX skew before backpressure).
    pub loopback_fifo_bytes: u64,
    /// NullHop MAC array size.
    pub nullhop_macs: u64,
    /// NullHop core clock.
    pub nullhop_clk_hz: f64,
    /// NullHop's on-chip output FIFO. When S2MM stops draining, this
    /// fills and the whole pipeline (including input consumption) stalls
    /// — the coupling that lets an unmanaged RX block TX (§IV).
    pub nullhop_out_fifo_bytes: u64,
    /// Per-layer configuration/registers phase inside NullHop.
    pub nullhop_config_ns: u64,
    /// Fraction of zero-operand MAC slots NullHop actually skips (its
    /// sparse decoder is not perfect; derated from the NullHop paper).
    pub nullhop_skip_efficiency: f64,

    // ---- Background load ---------------------------------------------------
    /// DDR bandwidth consumed by other processes (the CPU requester in
    /// the arbiter, lowest priority). 0 disables background traffic.
    /// The AB-LOAD ablation sweeps this to show how a loaded PS degrades
    /// each driver's transfers.
    pub bg_mem_bps: f64,
    /// Burst size of the background stream.
    pub bg_burst_bytes: u64,
    /// Watchdog on every wait primitive, in simulated time: a transfer
    /// that has not completed by then is declared blocked even if
    /// background traffic keeps the calendar alive.
    pub wait_deadline_ns: u64,

    // ---- Misc ---------------------------------------------------------------
    /// RNG seed for jitter and workload generation.
    pub seed: u64,
    /// Gaussian jitter applied to OS costs (stddev as a fraction of the
    /// mean); 0 disables jitter for bit-deterministic tests.
    pub os_jitter_frac: f64,
    /// Event-calendar backend (`"wheel"` or `"heap"`). Both produce
    /// bit-identical timelines (enforced by the equivalence gate); the
    /// wheel is the fast default, the heap the reference.
    pub calendar: CalendarKind,
    /// Fault-injection rates + recovery knobs (see [`crate::sim::fault`]).
    /// All rates default to zero, which keeps the whole subsystem inert:
    /// the fault-free timeline is bit-identical with or without it
    /// (enforced by `rust/tests/engine_equivalence.rs`).
    pub faults: FaultConfig,
    /// Multi-tenant serving workload (see [`crate::workload`]): tenant
    /// count, arrival processes, rates, deadlines, queue bounds, shed
    /// and QoS policies. Only the `serve`/`serve-sweep` paths read it;
    /// every other experiment is unaffected by these knobs.
    pub workload: WorkloadConfig,
    /// Memory-path axis (see [`crate::memory::path`]): copy-through vs
    /// zero-copy, ACP vs HP port, and the coherency cost knobs. Defaults
    /// to copy-through, under which no driver reads any other field of
    /// the struct — the timeline is bit-identical to the pre-subsystem
    /// simulator (enforced by `rust/tests/memory_path.rs`).
    pub memory: MemoryConfig,
    /// Fleet topology and placement (see [`crate::cluster`]): board
    /// count, per-board hardware profiles, placement policy, spill/steal
    /// and the board-failure schedule. Only the `cluster`/`cluster-sweep`
    /// paths read it.
    pub cluster: ClusterConfig,
    /// Per-layer co-scheduling knobs (see [`crate::coordinator::model`]):
    /// cross-layer weight prefetch and adjacent-layer fusion. Defaults
    /// off; only the `model-sweep` runner reads the block, so every
    /// other experiment's timeline is untouched by it.
    pub model: ModelConfig,
    /// Telemetry knobs (see [`crate::obs`]): the metrics registry,
    /// frame-lifecycle spans and the windowed time-series recorder.
    /// Defaults off; observation never alters simulated time, so even a
    /// fully enabled block leaves every timeline bit-identical
    /// (enforced by `rust/tests/telemetry.rs`).
    pub obs: ObsConfig,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            // DDR: 64-bit HP port @ 150 MHz = 1200 MB/s raw; ~85% efficient.
            ddr_bandwidth_bps: 1.02e9,
            ddr_latency_ns: 150,
            ddr_turnaround_ns: 45,
            ddr_engine_weights: vec![1],
            num_engines: 1,

            // AXI-Stream: 32-bit datamover @ 100 MHz (the NullHop
            // integration's stream width; calibrated against Table I's
            // TX ~0.0054 µs/B).
            stream_bandwidth_bps: 400e6,
            max_burst_bytes: 2048,
            mm2s_fifo_bytes: 4096,
            s2mm_fifo_bytes: 4096,
            desc_fetch_ns: 180,
            reg_write_ns: 120,
            reg_read_ns: 150,

            memcpy_bw_cached_bps: 1.35e9,
            memcpy_bw_ddr_bps: 620e6,
            memcpy_cache_threshold_bytes: 256 * 1024,
            memcpy_dma_contention: 0.82,
            uncached_copy_factor: 0.42,

            syscall_entry_ns: 900,
            syscall_exit_ns: 700,
            ctx_switch_ns: 4_200,
            gic_latency_ns: 300,
            isr_entry_ns: 2_300,
            isr_dma_handler_ns: 3_000,
            wake_latency_ns: 4_500,
            timeslice_ns: 10_000_000,
            sched_poll_period_ns: 100_000,

            user_setup_ns: 600,
            poll_loop_overhead_ns: 60,
            polling_dma_penalty: 1.04,
            kernel_submit_ns: 9_000,
            kernel_desc_build_ns: 800,
            kernel_sg_chunk_bytes: 256 * 1024,
            kernel_cache_flush_bps: 250e6,
            blocks_chunk_bytes: 64 * 1024,

            loopback_latency_ns: 240,
            loopback_fifo_bytes: 8 * 1024,
            nullhop_macs: 128,
            // The real core ran at 60 MHz; our RoShamBo geometry is an
            // approximation with ~2.4x fewer dense MACs than the deployed
            // net, so the effective clock folds that ratio in (DESIGN.md
            // §6 calibration anchors).
            nullhop_clk_hz: 25e6,
            nullhop_out_fifo_bytes: 16 * 1024,
            nullhop_config_ns: 2_500,
            nullhop_skip_efficiency: 0.75,

            bg_mem_bps: 0.0,
            bg_burst_bytes: 1024,
            wait_deadline_ns: 10_000_000_000, // 10 s of simulated time

            seed: 0xC0DE5EED,
            os_jitter_frac: 0.0,
            calendar: CalendarKind::Wheel,
            faults: FaultConfig::none(),
            workload: WorkloadConfig::default(),
            memory: MemoryConfig::none(),
            cluster: ClusterConfig::none(),
            model: ModelConfig::none(),
            obs: ObsConfig::none(),
        }
    }
}

macro_rules! config_fields {
    ($($field:ident : $kind:ident),* $(,)?) => {
        impl SimConfig {
            /// Apply overrides from a parsed JSON object; unknown keys are an
            /// error (catches typos in calibration sweeps).
            pub fn apply_json(&mut self, v: &Json) -> anyhow::Result<()> {
                let obj = v
                    .as_obj()
                    .ok_or_else(|| anyhow::anyhow!("config root must be a JSON object"))?;
                for (k, val) in obj {
                    match k.as_str() {
                        $(stringify!($field) => {
                            config_fields!(@set self, $field, $kind, val, k);
                        })*
                        _ => anyhow::bail!("unknown config key: {k}"),
                    }
                }
                Ok(())
            }

            /// Serialize the full config (for EXPERIMENTS.md provenance).
            pub fn to_json(&self) -> Json {
                Json::obj(vec![
                    $((stringify!($field), config_fields!(@get self, $field, $kind)),)*
                ])
            }
        }
    };
    (@set $self:ident, $field:ident, f64, $val:ident, $k:ident) => {
        $self.$field = $val
            .as_f64()
            .ok_or_else(|| anyhow::anyhow!("config key {} must be a number", $k))?;
    };
    (@set $self:ident, $field:ident, u64, $val:ident, $k:ident) => {
        $self.$field = $val
            .as_u64()
            .ok_or_else(|| anyhow::anyhow!("config key {} must be a non-negative integer", $k))?;
    };
    (@set $self:ident, $field:ident, vec_u64, $val:ident, $k:ident) => {
        $self.$field = $val
            .as_arr()
            .ok_or_else(|| anyhow::anyhow!("config key {} must be an array", $k))?
            .iter()
            .map(|x| {
                x.as_u64().ok_or_else(|| {
                    anyhow::anyhow!("config key {} must hold non-negative integers", $k)
                })
            })
            .collect::<anyhow::Result<Vec<u64>>>()?;
    };
    (@set $self:ident, $field:ident, calendar, $val:ident, $k:ident) => {
        $self.$field = match $val.as_str() {
            Some("wheel") => CalendarKind::Wheel,
            Some("heap") => CalendarKind::Heap,
            _ => anyhow::bail!("config key {} must be \"wheel\" or \"heap\"", $k),
        };
    };
    (@set $self:ident, $field:ident, faults, $val:ident, $k:ident) => {
        $self.$field.apply_json($val)?;
    };
    (@set $self:ident, $field:ident, workload, $val:ident, $k:ident) => {
        $self.$field.apply_json($val)?;
    };
    (@set $self:ident, $field:ident, memory, $val:ident, $k:ident) => {
        $self.$field.apply_json($val)?;
    };
    (@set $self:ident, $field:ident, cluster, $val:ident, $k:ident) => {
        $self.$field.apply_json($val)?;
    };
    (@set $self:ident, $field:ident, model, $val:ident, $k:ident) => {
        $self.$field.apply_json($val)?;
    };
    (@set $self:ident, $field:ident, obs, $val:ident, $k:ident) => {
        $self.$field.apply_json($val)?;
    };
    (@get $self:ident, $field:ident, f64) => { Json::num($self.$field) };
    (@get $self:ident, $field:ident, u64) => { Json::num($self.$field as f64) };
    (@get $self:ident, $field:ident, faults) => { $self.$field.to_json() };
    (@get $self:ident, $field:ident, workload) => { $self.$field.to_json() };
    (@get $self:ident, $field:ident, memory) => { $self.$field.to_json() };
    (@get $self:ident, $field:ident, cluster) => { $self.$field.to_json() };
    (@get $self:ident, $field:ident, model) => { $self.$field.to_json() };
    (@get $self:ident, $field:ident, obs) => { $self.$field.to_json() };
    (@get $self:ident, $field:ident, vec_u64) => {
        Json::Arr($self.$field.iter().map(|&x| Json::num(x as f64)).collect())
    };
    (@get $self:ident, $field:ident, calendar) => { Json::str($self.$field.label()) };
}

config_fields! {
    ddr_bandwidth_bps: f64,
    ddr_latency_ns: u64,
    ddr_turnaround_ns: u64,
    ddr_engine_weights: vec_u64,
    num_engines: u64,
    stream_bandwidth_bps: f64,
    max_burst_bytes: u64,
    mm2s_fifo_bytes: u64,
    s2mm_fifo_bytes: u64,
    desc_fetch_ns: u64,
    reg_write_ns: u64,
    reg_read_ns: u64,
    memcpy_bw_cached_bps: f64,
    memcpy_bw_ddr_bps: f64,
    memcpy_cache_threshold_bytes: u64,
    memcpy_dma_contention: f64,
    uncached_copy_factor: f64,
    syscall_entry_ns: u64,
    syscall_exit_ns: u64,
    ctx_switch_ns: u64,
    gic_latency_ns: u64,
    isr_entry_ns: u64,
    isr_dma_handler_ns: u64,
    wake_latency_ns: u64,
    timeslice_ns: u64,
    sched_poll_period_ns: u64,
    user_setup_ns: u64,
    poll_loop_overhead_ns: u64,
    polling_dma_penalty: f64,
    kernel_submit_ns: u64,
    kernel_desc_build_ns: u64,
    kernel_sg_chunk_bytes: u64,
    kernel_cache_flush_bps: f64,
    blocks_chunk_bytes: u64,
    loopback_latency_ns: u64,
    loopback_fifo_bytes: u64,
    nullhop_macs: u64,
    nullhop_clk_hz: f64,
    nullhop_out_fifo_bytes: u64,
    nullhop_config_ns: u64,
    nullhop_skip_efficiency: f64,
    bg_mem_bps: f64,
    bg_burst_bytes: u64,
    wait_deadline_ns: u64,
    seed: u64,
    os_jitter_frac: f64,
    calendar: calendar,
    faults: faults,
    workload: workload,
    memory: memory,
    cluster: cluster,
    model: model,
    obs: obs,
}

impl SimConfig {
    /// The *construction shape* of this config: every field
    /// [`crate::system::System::new`] and the device constructors read,
    /// with the fields they do **not** read normalised away. Two configs
    /// with equal shapes build bit-identical `System`s modulo the
    /// `cfg.seed`-derived OS-jitter RNG stream, which
    /// [`crate::system::System::fork`] re-derives per fork.
    ///
    /// Normalised out: `seed` (only consumed by `OsCosts`, re-derived on
    /// fork; `faults.seed` is a *separate* stream and stays in the
    /// shape), and the `workload`/`cluster`/`model` blocks, which only
    /// the serve loop, the fleet router and the model runner read — at
    /// run time, from the forked system's own `cfg` copy.
    pub fn construction_shape(&self) -> SimConfig {
        let mut c = self.clone();
        c.seed = 0;
        c.workload = WorkloadConfig::default();
        c.cluster = ClusterConfig::default();
        c.model = ModelConfig::default();
        c
    }

    /// Whether `self` and `other` build bit-identical `System`s (modulo
    /// the per-fork jitter stream) — the snapshot-cache key predicate.
    pub fn same_construction_shape(&self, other: &SimConfig) -> bool {
        self.construction_shape() == other.construction_shape()
    }

    /// Load a config: defaults overridden by the JSON file at `path`.
    pub fn load(path: &Path) -> anyhow::Result<SimConfig> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading config {}: {e}", path.display()))?;
        let json = Json::parse(&text)?;
        let mut cfg = SimConfig::default();
        cfg.apply_json(&json)?;
        cfg.validate()?;
        Ok(cfg)
    }

    /// Sanity checks: bandwidths positive, factors in range, FIFOs can hold
    /// at least one burst.
    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.ddr_bandwidth_bps > 0.0, "ddr_bandwidth_bps must be > 0");
        anyhow::ensure!(self.stream_bandwidth_bps > 0.0, "stream_bandwidth_bps must be > 0");
        anyhow::ensure!(self.memcpy_bw_cached_bps > 0.0, "memcpy_bw_cached_bps must be > 0");
        anyhow::ensure!(self.memcpy_bw_ddr_bps > 0.0, "memcpy_bw_ddr_bps must be > 0");
        anyhow::ensure!(self.max_burst_bytes > 0, "max_burst_bytes must be > 0");
        anyhow::ensure!(
            self.mm2s_fifo_bytes >= self.max_burst_bytes,
            "MM2S FIFO smaller than one burst would deadlock the engine"
        );
        anyhow::ensure!(
            self.s2mm_fifo_bytes >= self.max_burst_bytes,
            "S2MM FIFO smaller than one burst would deadlock the engine"
        );
        anyhow::ensure!(
            self.kernel_sg_chunk_bytes > 0 && self.blocks_chunk_bytes > 0,
            "chunk sizes must be > 0"
        );
        anyhow::ensure!(self.kernel_cache_flush_bps > 0.0, "kernel_cache_flush_bps must be > 0");
        anyhow::ensure!(self.bg_mem_bps >= 0.0, "bg_mem_bps must be >= 0");
        anyhow::ensure!(self.bg_burst_bytes > 0, "bg_burst_bytes must be > 0");
        anyhow::ensure!(self.wait_deadline_ns > 0, "wait_deadline_ns must be > 0");
        anyhow::ensure!(
            self.num_engines >= 1
                && self.num_engines as usize <= crate::sim::event::MAX_ENGINES,
            "num_engines must be in [1, {}]",
            crate::sim::event::MAX_ENGINES
        );
        anyhow::ensure!(
            !self.ddr_engine_weights.is_empty()
                && self.ddr_engine_weights.iter().all(|&w| w >= 1),
            "ddr_engine_weights must be non-empty with every weight >= 1"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.memcpy_dma_contention),
            "memcpy_dma_contention must be in [0,1]"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.uncached_copy_factor),
            "uncached_copy_factor must be in [0,1]"
        );
        anyhow::ensure!(
            self.polling_dma_penalty >= 1.0,
            "polling_dma_penalty is a slowdown, must be >= 1"
        );
        anyhow::ensure!(
            (0.0..=1.0).contains(&self.nullhop_skip_efficiency),
            "nullhop_skip_efficiency must be in [0,1]"
        );
        anyhow::ensure!(self.nullhop_macs > 0 && self.nullhop_clk_hz > 0.0, "nullhop params");
        anyhow::ensure!(
            (0.0..=0.5).contains(&self.os_jitter_frac),
            "os_jitter_frac must be in [0, 0.5]"
        );
        self.faults.validate()?;
        self.workload.validate()?;
        self.memory.validate()?;
        self.cluster.validate()?;
        self.model.validate()?;
        self.obs.validate()?;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        SimConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_identity() {
        let cfg = SimConfig::default();
        let json = cfg.to_json();
        let mut cfg2 = SimConfig::default();
        cfg2.ddr_latency_ns = 0; // perturb, then restore from json
        cfg2.apply_json(&json).unwrap();
        assert_eq!(cfg, cfg2);
    }

    #[test]
    fn override_single_key() {
        let mut cfg = SimConfig::default();
        cfg.apply_json(&Json::parse(r#"{"ddr_latency_ns": 99}"#).unwrap()).unwrap();
        assert_eq!(cfg.ddr_latency_ns, 99);
        // Everything else untouched.
        assert_eq!(cfg.reg_read_ns, SimConfig::default().reg_read_ns);
    }

    #[test]
    fn unknown_key_rejected() {
        let mut cfg = SimConfig::default();
        let err = cfg.apply_json(&Json::parse(r#"{"ddr_latencyns": 99}"#).unwrap());
        assert!(err.is_err(), "typo'd key must be rejected");
    }

    #[test]
    fn wrong_type_rejected() {
        let mut cfg = SimConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"ddr_latency_ns": "fast"}"#).unwrap()).is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"ddr_latency_ns": -5}"#).unwrap()).is_err());
    }

    #[test]
    fn validation_catches_deadlocky_fifo() {
        let mut cfg = SimConfig::default();
        cfg.mm2s_fifo_bytes = cfg.max_burst_bytes - 1;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn engine_fields_roundtrip_and_validate() {
        let mut cfg = SimConfig::default();
        cfg.apply_json(
            &Json::parse(r#"{"num_engines": 4, "ddr_engine_weights": [3, 1]}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.num_engines, 4);
        assert_eq!(cfg.ddr_engine_weights, vec![3, 1]);
        cfg.validate().unwrap();
        let json = cfg.to_json();
        let mut cfg2 = SimConfig::default();
        cfg2.apply_json(&json).unwrap();
        assert_eq!(cfg, cfg2);

        let mut bad = SimConfig::default();
        bad.num_engines = 0;
        assert!(bad.validate().is_err());
        let mut bad = SimConfig::default();
        bad.num_engines = 99;
        assert!(bad.validate().is_err());
        let mut bad = SimConfig::default();
        bad.ddr_engine_weights = vec![];
        assert!(bad.validate().is_err());
        let mut bad = SimConfig::default();
        bad.ddr_engine_weights = vec![0];
        assert!(bad.validate().is_err());
    }

    #[test]
    fn calendar_key_roundtrips_and_rejects_junk() {
        let mut cfg = SimConfig::default();
        assert_eq!(cfg.calendar, CalendarKind::Wheel);
        cfg.apply_json(&Json::parse(r#"{"calendar": "heap"}"#).unwrap()).unwrap();
        assert_eq!(cfg.calendar, CalendarKind::Heap);
        let json = cfg.to_json();
        assert_eq!(json.get("calendar").as_str(), Some("heap"));
        let mut cfg2 = SimConfig::default();
        cfg2.apply_json(&json).unwrap();
        assert_eq!(cfg, cfg2);
        assert!(cfg.apply_json(&Json::parse(r#"{"calendar": "ring"}"#).unwrap()).is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"calendar": 3}"#).unwrap()).is_err());
    }

    #[test]
    fn faults_key_roundtrips_and_validates() {
        let mut cfg = SimConfig::default();
        cfg.apply_json(
            &Json::parse(r#"{"faults": {"dma_error_rate": 0.01, "retry_limit": 5}}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.faults.dma_error_rate, 0.01);
        assert_eq!(cfg.faults.retry_limit, 5);
        cfg.validate().unwrap();
        let json = cfg.to_json();
        let mut cfg2 = SimConfig::default();
        cfg2.apply_json(&json).unwrap();
        assert_eq!(cfg, cfg2);
        // Unknown nested key and out-of-range rate both rejected.
        let mut cfg = SimConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"faults": {"bogus": 1}}"#).unwrap()).is_err());
        let mut cfg = SimConfig::default();
        cfg.faults.dma_error_rate = 2.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn workload_key_roundtrips_and_validates() {
        let mut cfg = SimConfig::default();
        cfg.apply_json(
            &Json::parse(r#"{"workload": {"tenants": 6, "policy": "edf", "queue_cap": 3}}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.workload.tenants, 6);
        assert_eq!(cfg.workload.queue_cap, 3);
        cfg.validate().unwrap();
        let json = cfg.to_json();
        let mut cfg2 = SimConfig::default();
        cfg2.apply_json(&json).unwrap();
        assert_eq!(cfg, cfg2);
        // Unknown nested key and out-of-range value both rejected.
        let mut cfg = SimConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"workload": {"bogus": 1}}"#).unwrap()).is_err());
        let mut cfg = SimConfig::default();
        cfg.workload.queue_cap = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn memory_key_roundtrips_and_validates() {
        use crate::memory::path::{DmaPortKind, MemoryPath};
        let mut cfg = SimConfig::default();
        cfg.apply_json(
            &Json::parse(r#"{"memory": {"path": "zero", "port": "acp", "flush_bps": 2e9}}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.memory.path, MemoryPath::ZeroCopy);
        assert_eq!(cfg.memory.port, DmaPortKind::Acp);
        assert_eq!(cfg.memory.flush_bps, 2e9);
        assert!(cfg.memory.is_zero_copy());
        cfg.validate().unwrap();
        let json = cfg.to_json();
        let mut cfg2 = SimConfig::default();
        cfg2.apply_json(&json).unwrap();
        assert_eq!(cfg, cfg2);
        // Unknown nested key and out-of-range value both rejected.
        let mut cfg = SimConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"memory": {"bogus": 1}}"#).unwrap()).is_err());
        let mut cfg = SimConfig::default();
        cfg.memory.acp_cpu_derate = 2.0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn cluster_key_roundtrips_and_validates() {
        use crate::cluster::{BoardKind, PlacementKind};
        let mut cfg = SimConfig::default();
        cfg.apply_json(
            &Json::parse(
                r#"{"cluster": {"boards": 3, "profiles": ["zynq7000", "ultrascale"],
                    "placement": "consistent-hash", "steal": true}}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(cfg.cluster.boards, 3);
        assert_eq!(cfg.cluster.profiles, vec![BoardKind::Zynq7000, BoardKind::Ultrascale]);
        assert_eq!(cfg.cluster.placement, PlacementKind::ConsistentHash);
        assert!(cfg.cluster.steal);
        cfg.validate().unwrap();
        let json = cfg.to_json();
        let mut cfg2 = SimConfig::default();
        cfg2.apply_json(&json).unwrap();
        assert_eq!(cfg, cfg2);
        // Unknown nested key and out-of-range value both rejected.
        let mut cfg = SimConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"cluster": {"bogus": 1}}"#).unwrap()).is_err());
        let mut cfg = SimConfig::default();
        cfg.cluster.boards = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn model_key_roundtrips_and_validates() {
        let mut cfg = SimConfig::default();
        assert!(!cfg.model.prefetch && !cfg.model.fusion, "co-scheduling must default off");
        let j = r#"{"model": {"prefetch": true, "fusion": true, "fusion_max_bytes": 4096}}"#;
        cfg.apply_json(&Json::parse(j).unwrap()).unwrap();
        assert!(cfg.model.prefetch);
        assert!(cfg.model.fusion);
        assert_eq!(cfg.model.fusion_max_bytes, 4096);
        cfg.validate().unwrap();
        let json = cfg.to_json();
        let mut cfg2 = SimConfig::default();
        cfg2.apply_json(&json).unwrap();
        assert_eq!(cfg, cfg2);
        // Unknown nested key and out-of-range value both rejected.
        let mut cfg = SimConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"model": {"bogus": 1}}"#).unwrap()).is_err());
        let mut cfg = SimConfig::default();
        cfg.model.fusion_max_bytes = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn obs_key_roundtrips_and_validates() {
        let mut cfg = SimConfig::default();
        assert!(!cfg.obs.enabled, "telemetry must default off");
        let j = r#"{"obs": {"enabled": true, "window_ns": 5000000, "max_spans": 256,
                    "spans": false, "timeseries": true}}"#;
        cfg.apply_json(&Json::parse(j).unwrap()).unwrap();
        assert!(cfg.obs.enabled);
        assert!(!cfg.obs.spans);
        assert_eq!(cfg.obs.window_ns, 5_000_000);
        assert_eq!(cfg.obs.max_spans, 256);
        cfg.validate().unwrap();
        let json = cfg.to_json();
        let mut cfg2 = SimConfig::default();
        cfg2.apply_json(&json).unwrap();
        assert_eq!(cfg, cfg2);
        // Unknown nested key and out-of-range value both rejected.
        let mut cfg = SimConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"obs": {"bogus": 1}}"#).unwrap()).is_err());
        let mut cfg = SimConfig::default();
        cfg.obs.window_ns = 0;
        assert!(cfg.validate().is_err());
    }

    #[test]
    fn validation_catches_bad_factors() {
        let mut cfg = SimConfig::default();
        cfg.polling_dma_penalty = 0.9;
        assert!(cfg.validate().is_err());
        let mut cfg = SimConfig::default();
        cfg.memcpy_dma_contention = 1.5;
        assert!(cfg.validate().is_err());
    }
}
