//! # psoc-dma — HW/SW co-design SoC memory-transfer study, reproduced
//!
//! Reproduction of *"Performance evaluation over HW/SW co-design SoC memory
//! transfers for a CNN accelerator"* (Rios-Navarro et al., 2018).
//!
//! The paper measures, on a Xilinx Zynq-7100 PSoC, how three software
//! schemes for driving the AXI-DMA engine between the ARM Processing
//! System and the Programmable Logic compare: **user-level polling**,
//! **user-level scheduled**, and a **kernel-level interrupt-driven
//! driver** — across transfer sizes (loop-back sweep, Fig. 4/5) and on a
//! real CNN accelerator workload (NullHop running the RoShamBo network,
//! Table I).
//!
//! We do not have the hardware, so the whole platform is rebuilt as a
//! calibrated **discrete-event simulator** (see `DESIGN.md`):
//!
//! * [`sim`] — event calendar, virtual ns clock, deterministic PRNG,
//!   and the seeded fault-injection plan ([`sim::fault`]) that stress-
//!   tests the drivers with DMA errors, descriptor corruption, lost/
//!   delayed IRQs and DDR contention bursts — every failure replayable
//!   from its seed (DESIGN.md §10);
//! * [`memory`] — DDR3 controller + arbitration, CMA bounce-buffer
//!   allocator, CPU memcpy cost model;
//! * [`axi`] — AXI4-Stream FIFOs, scatter-gather descriptors, and the
//!   AXI-DMA engine (MM2S/S2MM channel state machines);
//! * [`os`] — scheduler, syscall/context-switch/interrupt cost model;
//! * [`accel`] — the PL devices: loop-back core and the NullHop CNN
//!   accelerator timing model (one instance per engine);
//! * [`system`] — the dispatcher that owns all components and routes
//!   events between them; also the software-process facade the drivers
//!   program against. A system carries `SimConfig::num_engines`
//!   independent AXI-DMA engines ([`system::DmaPort`]: channel pair +
//!   FIFOs + register block + IRQ lines + PL device each), all
//!   arbitrating over the shared DDR with per-engine weights
//!   (DESIGN.md §7);
//! * [`drivers`] — the transfer-management schemes behind the
//!   [`drivers::TransferScheme`] trait: the paper's three (user polling /
//!   user scheduled / kernel IRQ) × {single,double}-buffer ×
//!   {Unique,Blocks} partitioning, plus the multi-queue kernel scheme
//!   that stripes one payload across every engine. Each scheme offers
//!   the blocking `transfer` and the split-phase `submit`/`complete`
//!   pair;
//! * [`cnn`] — layer descriptors (RoShamBo, VGG19) and NullHop's sparse
//!   feature-map encoding;
//! * [`sensor`] — DAVIS dynamic-vision-sensor event generator + frame
//!   histogramming (the PS-side workload);
//! * [`runtime`] — PJRT client that loads the AOT-compiled JAX/Pallas
//!   CNN (HLO text in `artifacts/`) and executes the *numerics* that the
//!   simulator only times;
//! * [`coordinator`] — the per-layer pipeline fusing simulated transfer
//!   timing with real accelerator numerics, plus metrics. Three execution
//!   modes: the paper's sequential [`coordinator::run_frame`], the
//!   frame-pipelined [`coordinator::run_batch`] batch scheduler that
//!   keeps up to `depth` frames in flight across the engines, and the
//!   multi-tenant [`coordinator::serve`] loop that multiplexes tenant
//!   streams onto the engine pool under a QoS policy;
//! * [`cluster`] — fleet-scale serving: N simulated boards (possibly
//!   heterogeneous profiles) behind a front-end balancer with pluggable
//!   tenant placement, cross-board spill/steal, and seeded deterministic
//!   board-failure failover (DESIGN.md §13);
//! * [`experiment`] — the unified `Experiment` trait + registry every
//!   CLI command dispatches through (one place to add a command: name,
//!   flags, runner, renderers);
//! * [`workload`] — the serving workload model behind `serve`: seeded
//!   open-/closed-loop stream generators, bounded admission queues with
//!   shed policies, pluggable QoS scheduling (FIFO / weighted DRR /
//!   priority-with-aging / EDF) and per-tenant SLO accounting
//!   (DESIGN.md §11);
//! * [`report`] — figure/table regeneration (Fig. 4, Fig. 5, Table I,
//!   the scaling grid, ablations).
//!
//! Python (JAX + Pallas) runs only at `make artifacts`; the rust binary is
//! self-contained afterwards.

// The seed predates clippy enforcement; these lints are stylistic and
// firing all over the calibrated-constant test fixtures.
#![allow(clippy::field_reassign_with_default)]
#![allow(clippy::needless_range_loop)]
#![allow(clippy::too_many_arguments)]
#![allow(clippy::manual_div_ceil)]

pub mod accel;
pub mod axi;
pub mod cluster;
pub mod cnn;
pub mod config;
pub mod coordinator;
pub mod drivers;
pub mod experiment;
pub mod memory;
pub mod obs;
pub mod os;
pub mod report;
pub mod runtime;
pub mod sensor;
pub mod sim;
pub mod system;
pub mod util;
pub mod workload;

/// Crate version (for `--version` and experiment provenance).
pub fn version() -> &'static str {
    env!("CARGO_PKG_VERSION")
}
