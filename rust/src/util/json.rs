//! Minimal JSON parser/emitter.
//!
//! The sandbox has no network access to crates.io, so `serde`/`serde_json`
//! are unavailable; this module is a small, well-tested replacement used
//! for three things only: the artifact manifest written by
//! `python/compile/aot.py`, experiment reports, and simulator config
//! overrides. It supports the full JSON grammar (RFC 8259) minus `\u`
//! surrogate pairs outside the BMP, which none of our producers emit.

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value. Objects use a `BTreeMap` so emission is
/// deterministic (sorted keys) — handy for golden-file tests.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ParseError {
    pub pos: usize,
    pub msg: String,
}

impl fmt::Display for ParseError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json parse error at byte {}: {}", self.pos, self.msg)
    }
}

impl std::error::Error for ParseError {}

impl Json {
    pub fn parse(s: &str) -> Result<Json, ParseError> {
        let mut p = Parser { b: s.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.b.len() {
            return Err(p.err("trailing characters"));
        }
        Ok(v)
    }

    // ---- typed accessors -------------------------------------------------

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        match self {
            Json::Num(n) if *n >= 0.0 && n.fract() == 0.0 && *n <= u64::MAX as f64 => {
                Some(*n as u64)
            }
            _ => None,
        }
    }

    pub fn as_i64(&self) -> Option<i64> {
        match self {
            Json::Num(n) if n.fract() == 0.0 && *n >= i64::MIN as f64 && *n <= i64::MAX as f64 => {
                Some(*n as i64)
            }
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// `obj["key"]`-style access; returns `Json::Null` for missing keys or
    /// non-objects so lookups can be chained.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(m) => m.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn idx(&self, i: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(v) => v.get(i).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // ---- construction helpers -------------------------------------------

    pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr(items: Vec<Json>) -> Json {
        Json::Arr(items)
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    pub fn num(n: f64) -> Json {
        Json::Num(n)
    }

    /// Compact single-line emission.
    pub fn to_string_compact(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, None, 0);
        out
    }

    /// Pretty emission with 2-space indentation.
    pub fn to_string_pretty(&self) -> String {
        let mut out = String::new();
        self.write(&mut out, Some(2), 0);
        out.push('\n');
        out
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => out.push_str(&fmt_num(*n)),
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(items) => {
                if items.is_empty() {
                    out.push_str("[]");
                    return;
                }
                out.push('[');
                for (i, item) in items.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push(']');
            }
            Json::Obj(map) => {
                if map.is_empty() {
                    out.push_str("{}");
                    return;
                }
                out.push('{');
                for (i, (k, v)) in map.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline_indent(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    v.write(out, indent, depth + 1);
                }
                newline_indent(out, indent, depth);
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.to_string_compact())
    }
}

fn newline_indent(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn fmt_num(n: f64) -> String {
    if n.fract() == 0.0 && n.abs() < 1e15 {
        format!("{}", n as i64)
    } else {
        // Shortest roundtrip representation Rust gives us.
        format!("{n}")
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> ParseError {
        ParseError { pos: self.pos, msg: msg.to_string() }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek()?;
        self.pos += 1;
        Some(c)
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ' | b'\t' | b'\n' | b'\r')) {
            self.pos += 1;
        }
    }

    fn expect(&mut self, c: u8) -> Result<(), ParseError> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, ParseError> {
        if self.b[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(self.err(&format!("expected '{word}'")))
        }
    }

    fn value(&mut self) -> Result<Json, ParseError> {
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected a JSON value")),
        }
    }

    fn object(&mut self) -> Result<Json, ParseError> {
        self.expect(b'{')?;
        let mut map = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(map));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            self.skip_ws();
            let val = self.value()?;
            map.insert(key, val);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b'}') => return Ok(Json::Obj(map)),
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn array(&mut self) -> Result<Json, ParseError> {
        self.expect(b'[')?;
        let mut items = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(items));
        }
        loop {
            self.skip_ws();
            items.push(self.value()?);
            self.skip_ws();
            match self.bump() {
                Some(b',') => continue,
                Some(b']') => return Ok(Json::Arr(items)),
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, ParseError> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.bump() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => return Ok(s),
                Some(b'\\') => match self.bump() {
                    Some(b'"') => s.push('"'),
                    Some(b'\\') => s.push('\\'),
                    Some(b'/') => s.push('/'),
                    Some(b'n') => s.push('\n'),
                    Some(b't') => s.push('\t'),
                    Some(b'r') => s.push('\r'),
                    Some(b'b') => s.push('\u{8}'),
                    Some(b'f') => s.push('\u{c}'),
                    Some(b'u') => {
                        let mut code = 0u32;
                        for _ in 0..4 {
                            let c = self.bump().ok_or_else(|| self.err("bad \\u escape"))?;
                            let d = (c as char)
                                .to_digit(16)
                                .ok_or_else(|| self.err("bad hex digit in \\u"))?;
                            code = code * 16 + d;
                        }
                        s.push(
                            char::from_u32(code)
                                .ok_or_else(|| self.err("surrogate \\u escapes unsupported"))?,
                        );
                    }
                    _ => return Err(self.err("bad escape")),
                },
                Some(c) if c < 0x20 => return Err(self.err("control char in string")),
                Some(c) => {
                    // Re-assemble UTF-8 multibyte sequences byte-wise.
                    if c < 0x80 {
                        s.push(c as char);
                    } else {
                        let start = self.pos - 1;
                        let width = utf8_width(c);
                        let end = start + width;
                        if end > self.b.len() {
                            return Err(self.err("truncated utf-8"));
                        }
                        let chunk = std::str::from_utf8(&self.b[start..end])
                            .map_err(|_| self.err("invalid utf-8"))?;
                        s.push_str(chunk);
                        self.pos = end;
                    }
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, ParseError> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
            self.pos += 1;
        }
        if self.peek() == Some(b'.') {
            self.pos += 1;
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        if matches!(self.peek(), Some(b'e' | b'E')) {
            self.pos += 1;
            if matches!(self.peek(), Some(b'+' | b'-')) {
                self.pos += 1;
            }
            while matches!(self.peek(), Some(c) if c.is_ascii_digit()) {
                self.pos += 1;
            }
        }
        let txt = std::str::from_utf8(&self.b[start..self.pos]).unwrap();
        txt.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| self.err("invalid number"))
    }
}

fn utf8_width(first: u8) -> usize {
    match first {
        0xc0..=0xdf => 2,
        0xe0..=0xef => 3,
        0xf0..=0xf7 => 4,
        _ => 1,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" false ").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-3.5e2").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x\ny"}"#).unwrap();
        assert_eq!(v.get("a").idx(0).as_u64(), Some(1));
        assert!(v.get("a").idx(2).get("b").is_null());
        assert_eq!(v.get("c").as_str(), Some("x\ny"));
        assert!(v.get("missing").is_null());
    }

    #[test]
    fn parse_errors() {
        assert!(Json::parse("").is_err());
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("\"unterminated").is_err());
        assert!(Json::parse("12 34").is_err());
        assert!(Json::parse("{\"a\":1,}").is_err());
    }

    #[test]
    fn roundtrip_compact_and_pretty() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"o":{"k":-7}}"#;
        let v = Json::parse(src).unwrap();
        assert_eq!(v.to_string_compact(), src);
        let pretty = v.to_string_pretty();
        assert_eq!(Json::parse(&pretty).unwrap(), v);
    }

    #[test]
    fn escapes_roundtrip() {
        let v = Json::Str("tab\t nl\n quote\" back\\ unicode é".into());
        let s = v.to_string_compact();
        assert_eq!(Json::parse(&s).unwrap(), v);
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse(r#""é""#).unwrap(), Json::Str("é".into()));
    }

    #[test]
    fn u64_accessor_rejects_fraction_and_negative() {
        assert_eq!(Json::Num(1.5).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_u64(), None);
        assert_eq!(Json::Num(-1.0).as_i64(), Some(-1));
        assert_eq!(Json::Num(7.0).as_u64(), Some(7));
    }

    #[test]
    fn deterministic_key_order() {
        let v = Json::parse(r#"{"z":1,"a":2}"#).unwrap();
        assert_eq!(v.to_string_compact(), r#"{"a":2,"z":1}"#);
    }

    #[test]
    fn builders() {
        let v = Json::obj(vec![
            ("name", Json::str("fig4")),
            ("sizes", Json::arr(vec![Json::num(8.0), Json::num(16.0)])),
        ]);
        assert_eq!(v.get("name").as_str(), Some("fig4"));
        assert_eq!(v.get("sizes").as_arr().unwrap().len(), 2);
    }
}
