//! Dependency-free utilities: a minimal JSON parser/emitter (the sandbox
//! has no serde) and summary statistics for the reports and benches.

pub mod json;
pub mod stats;

pub use json::Json;
pub use stats::Summary;
