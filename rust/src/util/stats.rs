//! Small statistics helpers shared by the metrics collector, the report
//! printers, and the hand-rolled bench harness.

/// Summary statistics over a sample of `f64`s.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub p999: f64,
}

impl Summary {
    /// Empty-safe constructor: `None` for an empty sample. Report
    /// printers use this so a tenant (or cell) with zero completions
    /// renders as a dropped row instead of crashing the whole table.
    pub fn try_of(samples: &[f64]) -> Option<Summary> {
        if samples.is_empty() {
            return None;
        }
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Some(Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
            p999: percentile_sorted(&sorted, 99.9),
        })
    }

    pub fn of(samples: &[f64]) -> Summary {
        Summary::try_of(samples).expect("Summary::of on empty sample")
    }
}

/// Log-bucketed latency histogram: bucket `i > 0` covers `[2^(i-1), 2^i)`
/// nanoseconds, bucket 0 holds zeros. 64 buckets span the whole `u64`
/// range, so recording can never overflow the bucket table. Cheap to
/// record into, cheap to merge across tenants or sweep shards, and good
/// enough (half-bucket relative error with interpolation) for the tail
/// percentiles the serving reports print.
#[derive(Clone, Debug, PartialEq)]
pub struct LogHistogram {
    counts: [u64; 64],
    count: u64,
    sum: u64,
    /// Smallest recorded value; `u64::MAX` sentinel while empty (keeps
    /// the derived `PartialEq` exact for merge-vs-record equivalence).
    min: u64,
    max: u64,
}

// Hand-rolled: `[u64; 64]` has no derived `Default` (std stops at 32).
#[allow(clippy::derivable_impls)]
impl Default for LogHistogram {
    fn default() -> Self {
        LogHistogram { counts: [0; 64], count: 0, sum: 0, min: u64::MAX, max: 0 }
    }
}

impl LogHistogram {
    pub fn new() -> LogHistogram {
        LogHistogram::default()
    }

    #[inline]
    fn bucket(v: u64) -> usize {
        if v == 0 {
            0
        } else {
            64 - v.leading_zeros() as usize
        }
    }

    pub fn record(&mut self, v: u64) {
        self.counts[Self::bucket(v).min(63)] += 1;
        self.count += 1;
        self.sum = self.sum.saturating_add(v);
        self.min = self.min.min(v);
        self.max = self.max.max(v);
    }

    /// Fold another histogram in (per-tenant → aggregate tail reporting;
    /// sweep-shard → global).
    pub fn merge(&mut self, other: &LogHistogram) {
        for (a, b) in self.counts.iter_mut().zip(other.counts.iter()) {
            *a += b;
        }
        self.count += other.count;
        self.sum = self.sum.saturating_add(other.sum);
        self.min = self.min.min(other.min);
        self.max = self.max.max(other.max);
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn is_empty(&self) -> bool {
        self.count == 0
    }

    pub fn mean(&self) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        self.sum as f64 / self.count as f64
    }

    pub fn max(&self) -> u64 {
        self.max
    }

    /// Smallest recorded value (0 for an empty histogram).
    pub fn min(&self) -> u64 {
        if self.count == 0 {
            0
        } else {
            self.min
        }
    }

    /// Approximate percentile (`p` in `[0, 100]`), linearly interpolated
    /// inside the covering bucket. Empty histogram → `None`.
    pub fn percentile(&self, p: f64) -> Option<f64> {
        assert!((0.0..=100.0).contains(&p));
        if self.count == 0 {
            return None;
        }
        let rank = p / 100.0 * self.count as f64;
        let mut seen = 0u64;
        for (i, &c) in self.counts.iter().enumerate() {
            if c == 0 {
                continue;
            }
            if seen as f64 + c as f64 >= rank {
                let lo = if i == 0 { 0u64 } else { 1u64 << (i - 1) };
                let hi = if i == 0 { 1u64 } else { (1u64 << (i - 1)).saturating_mul(2) };
                let within = ((rank - seen as f64) / c as f64).clamp(0.0, 1.0);
                let v = lo as f64 + within * (hi - lo) as f64;
                // Never report outside the observed range: buckets are
                // wide (the covering bucket's lower bound can sit far
                // below the smallest recorded value, and the top bucket
                // far above the largest), while min/max are exact.
                return Some(v.clamp(self.min as f64, self.max as f64));
            }
            seen += c;
        }
        Some(self.max as f64)
    }
}

/// Linear-interpolation percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean (used for "by roughly what factor" comparisons in
/// EXPERIMENTS.md).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Format a byte count the way the paper's x-axes do (8B ... 6MB).
/// Units are monotone in the value: everything ≥ 1 KB renders in KB,
/// everything ≥ 1 MB in MB (exact multiples as integers, the rest with
/// one decimal) — a non-multiple like 1536 is "1.5KB", never "1536B".
pub fn fmt_bytes(b: u64) -> String {
    const KB: u64 = 1024;
    const MB: u64 = 1024 * 1024;
    if b >= MB {
        if b % MB == 0 {
            format!("{}MB", b / MB)
        } else {
            format!("{:.1}MB", b as f64 / MB as f64)
        }
    } else if b >= KB {
        if b % KB == 0 {
            format!("{}KB", b / KB)
        } else {
            format!("{:.1}KB", b as f64 / KB as f64)
        }
    } else {
        format!("{b}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p99, 7.0);
        assert_eq!(s.p999, 7.0);
    }

    #[test]
    fn summary_empty_is_none_not_panic() {
        assert!(Summary::try_of(&[]).is_none());
        assert!(Summary::try_of(&[1.0]).is_some());
    }

    #[test]
    fn summary_p999_tracks_extreme_tail() {
        // 999 fast samples + one slow outlier: p99 stays low, p99.9 sees it.
        let mut v = vec![1.0; 999];
        v.push(1000.0);
        let s = Summary::of(&v);
        assert!(s.p99 < 2.0, "p99 {}", s.p99);
        assert!(s.p999 > 2.0, "p999 {}", s.p999);
    }

    #[test]
    fn histogram_records_and_bounds_percentiles() {
        let mut h = LogHistogram::new();
        for v in [0u64, 1, 100, 1000, 1000, 1000, 10_000] {
            h.record(v);
        }
        assert_eq!(h.count(), 7);
        assert_eq!(h.max(), 10_000);
        let p50 = h.percentile(50.0).unwrap();
        assert!(p50 >= 100.0 && p50 <= 2048.0, "p50 {p50}");
        let p100 = h.percentile(100.0).unwrap();
        assert!(p100 <= 10_000.0);
        assert!(h.percentile(0.0).is_some());
        assert!((h.mean() - 13101.0 / 7.0).abs() < 1e-9);
    }

    #[test]
    fn histogram_empty_and_merge() {
        let mut a = LogHistogram::new();
        assert!(a.percentile(99.0).is_none());
        assert!(a.is_empty());
        let mut b = LogHistogram::new();
        a.record(10);
        b.record(1 << 40);
        b.record(1 << 40);
        a.merge(&b);
        assert_eq!(a.count(), 3);
        assert_eq!(a.max(), 1 << 40);
        // Merged tail dominated by b's slow samples.
        assert!(a.percentile(99.0).unwrap() > 1e9);
        // Merge is count-exact: same as recording everything into one.
        let mut c = LogHistogram::new();
        for v in [10u64, 1 << 40, 1 << 40] {
            c.record(v);
        }
        assert_eq!(a, c);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_bytes_axis_labels() {
        assert_eq!(fmt_bytes(8), "8B");
        assert_eq!(fmt_bytes(1024), "1KB");
        assert_eq!(fmt_bytes(65536), "64KB");
        assert_eq!(fmt_bytes(6 * 1024 * 1024), "6MB");
    }

    #[test]
    fn fmt_bytes_units_are_monotone() {
        // Regression: mid-range non-multiples used to fall through to
        // the raw-bytes branch ("1536B" between "1KB" and "2KB").
        assert_eq!(fmt_bytes(1536), "1.5KB");
        assert_eq!(fmt_bytes(2500), "2.4KB");
        assert_eq!(fmt_bytes(9999), "9.8KB");
        assert_eq!(fmt_bytes(1_500_000), "1.4MB");
        // Unit never regresses as the value grows.
        let unit = |s: &str| {
            if s.ends_with("MB") {
                2
            } else if s.ends_with("KB") {
                1
            } else {
                0
            }
        };
        let mut last = 0;
        for b in [8u64, 1000, 1024, 1536, 9999, 10_001, 65_536, 1_500_000, 6 << 20] {
            let u = unit(&fmt_bytes(b));
            assert!(u >= last, "unit regressed at {b}: {}", fmt_bytes(b));
            last = u;
        }
    }

    #[test]
    fn histogram_percentile_clamps_to_observed_range() {
        // 1000 lands in bucket [512, 1024): p0 used to report 512, far
        // below the smallest recorded value.
        let mut h = LogHistogram::new();
        h.record(1000);
        h.record(1000);
        assert_eq!(h.min(), 1000);
        assert_eq!(h.percentile(0.0).unwrap(), 1000.0);
        assert_eq!(h.percentile(100.0).unwrap(), 1000.0);
        // Every percentile of a single-valued histogram is that value.
        for p in [0.0, 25.0, 50.0, 99.9] {
            assert_eq!(h.percentile(p).unwrap(), 1000.0);
        }
    }

    #[test]
    fn histogram_min_survives_merge() {
        let mut a = LogHistogram::new();
        assert_eq!(a.min(), 0, "empty histogram reports 0");
        let mut b = LogHistogram::new();
        a.record(5000);
        b.record(700);
        a.merge(&b);
        assert_eq!(a.min(), 700);
        assert_eq!(a.max(), 5000);
        assert!(a.percentile(0.0).unwrap() >= 700.0);
        // Merging an empty histogram must not disturb the sentinel.
        let empty = LogHistogram::new();
        let before = a.clone();
        a.merge(&empty);
        assert_eq!(a, before);
    }
}
