//! Small statistics helpers shared by the metrics collector, the report
//! printers, and the hand-rolled bench harness.

/// Summary statistics over a sample of `f64`s.
#[derive(Clone, Debug, PartialEq)]
pub struct Summary {
    pub n: usize,
    pub mean: f64,
    pub stddev: f64,
    pub min: f64,
    pub max: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
}

impl Summary {
    pub fn of(samples: &[f64]) -> Summary {
        assert!(!samples.is_empty(), "Summary::of on empty sample");
        let n = samples.len();
        let mean = samples.iter().sum::<f64>() / n as f64;
        let var = if n > 1 {
            samples.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / (n - 1) as f64
        } else {
            0.0
        };
        let mut sorted = samples.to_vec();
        sorted.sort_by(|a, b| a.partial_cmp(b).unwrap());
        Summary {
            n,
            mean,
            stddev: var.sqrt(),
            min: sorted[0],
            max: sorted[n - 1],
            p50: percentile_sorted(&sorted, 50.0),
            p95: percentile_sorted(&sorted, 95.0),
            p99: percentile_sorted(&sorted, 99.0),
        }
    }
}

/// Linear-interpolation percentile over a pre-sorted slice.
pub fn percentile_sorted(sorted: &[f64], p: f64) -> f64 {
    assert!(!sorted.is_empty());
    assert!((0.0..=100.0).contains(&p));
    if sorted.len() == 1 {
        return sorted[0];
    }
    let rank = p / 100.0 * (sorted.len() - 1) as f64;
    let lo = rank.floor() as usize;
    let hi = rank.ceil() as usize;
    if lo == hi {
        sorted[lo]
    } else {
        let frac = rank - lo as f64;
        sorted[lo] * (1.0 - frac) + sorted[hi] * frac
    }
}

/// Geometric mean (used for "by roughly what factor" comparisons in
/// EXPERIMENTS.md).
pub fn geomean(xs: &[f64]) -> f64 {
    assert!(!xs.is_empty());
    let s: f64 = xs.iter().map(|x| x.max(1e-300).ln()).sum();
    (s / xs.len() as f64).exp()
}

/// Format a byte count the way the paper's x-axes do (8B ... 6MB).
pub fn fmt_bytes(b: u64) -> String {
    if b >= 1024 * 1024 && b % (1024 * 1024) == 0 {
        format!("{}MB", b / (1024 * 1024))
    } else if b >= 1024 && b % 1024 == 0 {
        format!("{}KB", b / 1024)
    } else if b >= 1_000_000 {
        format!("{:.1}MB", b as f64 / (1024.0 * 1024.0))
    } else if b >= 10_000 {
        format!("{:.1}KB", b as f64 / 1024.0)
    } else {
        format!("{b}B")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let s = Summary::of(&[1.0, 2.0, 3.0, 4.0, 5.0]);
        assert_eq!(s.n, 5);
        assert_eq!(s.mean, 3.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 5.0);
        assert_eq!(s.p50, 3.0);
        assert!((s.stddev - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_single_sample() {
        let s = Summary::of(&[7.0]);
        assert_eq!(s.stddev, 0.0);
        assert_eq!(s.p99, 7.0);
    }

    #[test]
    fn percentile_interpolates() {
        let sorted = [0.0, 10.0];
        assert_eq!(percentile_sorted(&sorted, 0.0), 0.0);
        assert_eq!(percentile_sorted(&sorted, 50.0), 5.0);
        assert_eq!(percentile_sorted(&sorted, 100.0), 10.0);
    }

    #[test]
    fn geomean_known() {
        assert!((geomean(&[1.0, 4.0]) - 2.0).abs() < 1e-12);
        assert!((geomean(&[2.0, 2.0, 2.0]) - 2.0).abs() < 1e-12);
    }

    #[test]
    fn fmt_bytes_axis_labels() {
        assert_eq!(fmt_bytes(8), "8B");
        assert_eq!(fmt_bytes(1024), "1KB");
        assert_eq!(fmt_bytes(65536), "64KB");
        assert_eq!(fmt_bytes(6 * 1024 * 1024), "6MB");
    }
}
