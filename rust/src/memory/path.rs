//! Memory-path configuration: the copy-through vs zero-copy axis and the
//! ACP/HP port coherency axis, JSON-configurable under the `memory` key
//! of [`crate::config::SimConfig`].
//!
//! The seed models the paper's measurement app faithfully: every frame is
//! staged through a bounce buffer with a CPU memcpy. Real co-design
//! stacks (NEURAghe-style shared-memory integration) eliminate that copy
//! by producing frames directly into DMA-visible contiguous regions. The
//! [`MemoryPath::ZeroCopy`] mode models that: no staging memcpy, cyclic
//! scatter-gather rings armed once and re-triggered per frame, and an
//! explicit cache-coherency cost charged per transfer instead:
//!
//! * [`DmaPortKind::Hp`] — the high-performance (non-coherent) AXI port.
//!   Full DDR bandwidth, but the CPU must clean the TX region before the
//!   engine reads it and invalidate the RX region before reading results
//!   (a fixed maintenance setup plus a per-byte line walk).
//! * [`DmaPortKind::Acp`] — the accelerator coherency port through the
//!   SCU. No cache maintenance at all, but every DMA byte snoops the L2:
//!   a per-byte sharing toll on the transfer and a derate on concurrent
//!   CPU memcpy bandwidth.
//!
//! The default is [`MemoryPath::CopyThrough`], and like
//! [`crate::sim::fault::FaultConfig`] the disabled axis is provably
//! inert: no driver reads any zero-copy knob, so the copy-through
//! timeline is bit-identical to the pre-subsystem simulator (enforced by
//! `rust/tests/memory_path.rs`).

use crate::util::json::Json;

/// Which buffer/driver boundary the transfer path uses.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum MemoryPath {
    /// Stage every frame through a bounce buffer (the paper's app).
    CopyThrough,
    /// Frames live in DMA-visible regions; no staging memcpy.
    ZeroCopy,
}

impl MemoryPath {
    pub fn label(self) -> &'static str {
        match self {
            MemoryPath::CopyThrough => "copy",
            MemoryPath::ZeroCopy => "zero",
        }
    }
}

/// Which PS port the DMA masters (only read on the zero-copy path).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum DmaPortKind {
    /// AXI_HP: full bandwidth, explicit flush/invalidate per transfer.
    Hp,
    /// ACP: cache-coherent through the SCU, contended per byte.
    Acp,
}

impl DmaPortKind {
    pub fn label(self) -> &'static str {
        match self {
            DmaPortKind::Hp => "hp",
            DmaPortKind::Acp => "acp",
        }
    }
}

/// Zero-copy memory-path knobs, nested under the `memory` config key.
#[derive(Clone, Debug, PartialEq)]
pub struct MemoryConfig {
    /// `"copy"` (default, bit-identical to the seed) or `"zero"`.
    pub path: MemoryPath,
    /// `"hp"` or `"acp"`; ignored while `path` is `"copy"`.
    pub port: DmaPortKind,
    /// Cache clean/invalidate line-walk throughput on the HP path
    /// (dcache ops by MVA over an already-resident region — much faster
    /// than the kernel bounce-buffer flush, which also misses).
    pub flush_bps: f64,
    /// Fixed cost of one maintenance operation (barrier + loop setup),
    /// paid per clean and per invalidate on the HP path.
    pub maintenance_setup_ns: u64,
    /// Effective rate of the ACP snoop toll: each transferred byte costs
    /// `1/acp_penalty_bps` seconds of SCU sharing overhead.
    pub acp_penalty_bps: f64,
    /// Multiplier (<= 1) on CPU memcpy bandwidth while ACP DMA traffic
    /// is in flight (snoops steal L2 tag bandwidth from the CPU).
    pub acp_cpu_derate: f64,
    /// Descriptor granularity of the cyclic SG rings.
    pub ring_chunk_bytes: u64,
}

impl Default for MemoryConfig {
    fn default() -> Self {
        MemoryConfig {
            path: MemoryPath::CopyThrough,
            port: DmaPortKind::Hp,
            // A9 dcache clean/invalidate by MVA sweeps resident lines at
            // roughly L2 fill bandwidth.
            flush_bps: 3.2e9,
            maintenance_setup_ns: 1_800,
            // ACP snoop toll: every byte crosses the SCU twice (tag probe
            // + fill), roughly halving the effective maintenance rate.
            acp_penalty_bps: 1.6e9,
            acp_cpu_derate: 0.85,
            ring_chunk_bytes: 256 * 1024,
        }
    }
}

impl MemoryConfig {
    /// The disabled configuration (copy-through).
    pub fn none() -> Self {
        MemoryConfig::default()
    }

    /// Does the zero-copy path engage? Drivers branch on exactly this,
    /// so copy-through never reads any other field of the struct.
    #[inline]
    pub fn is_zero_copy(&self) -> bool {
        self.path == MemoryPath::ZeroCopy
    }

    /// One label for the whole mode (path + port): `"copy"`,
    /// `"zero-hp"` or `"zero-acp"` — the serve and cluster reports'
    /// self-description, matching the `memory-sweep` mode column.
    pub fn mode_label(&self) -> &'static str {
        match self.path {
            MemoryPath::CopyThrough => "copy",
            MemoryPath::ZeroCopy => match self.port {
                DmaPortKind::Hp => "zero-hp",
                DmaPortKind::Acp => "zero-acp",
            },
        }
    }

    /// Apply overrides from the nested `memory` JSON object; unknown
    /// keys are an error.
    pub fn apply_json(&mut self, v: &Json) -> anyhow::Result<()> {
        let obj = v
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("memory must be a JSON object"))?;
        for (k, val) in obj {
            match k.as_str() {
                "path" => {
                    self.path = match val.as_str() {
                        Some("copy") => MemoryPath::CopyThrough,
                        Some("zero") => MemoryPath::ZeroCopy,
                        _ => anyhow::bail!("memory.path must be \"copy\" or \"zero\""),
                    };
                }
                "port" => {
                    self.port = match val.as_str() {
                        Some("hp") => DmaPortKind::Hp,
                        Some("acp") => DmaPortKind::Acp,
                        _ => anyhow::bail!("memory.port must be \"hp\" or \"acp\""),
                    };
                }
                "flush_bps" => {
                    self.flush_bps = val
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("memory key {k} must be a number"))?;
                }
                "maintenance_setup_ns" => {
                    self.maintenance_setup_ns = val.as_u64().ok_or_else(|| {
                        anyhow::anyhow!("memory key {k} must be a non-negative integer")
                    })?;
                }
                "acp_penalty_bps" => {
                    self.acp_penalty_bps = val
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("memory key {k} must be a number"))?;
                }
                "acp_cpu_derate" => {
                    self.acp_cpu_derate = val
                        .as_f64()
                        .ok_or_else(|| anyhow::anyhow!("memory key {k} must be a number"))?;
                }
                "ring_chunk_bytes" => {
                    self.ring_chunk_bytes = val.as_u64().ok_or_else(|| {
                        anyhow::anyhow!("memory key {k} must be a non-negative integer")
                    })?;
                }
                _ => anyhow::bail!("unknown memory key: {k}"),
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("path", Json::str(self.path.label())),
            ("port", Json::str(self.port.label())),
            ("flush_bps", Json::num(self.flush_bps)),
            ("maintenance_setup_ns", Json::num(self.maintenance_setup_ns as f64)),
            ("acp_penalty_bps", Json::num(self.acp_penalty_bps)),
            ("acp_cpu_derate", Json::num(self.acp_cpu_derate)),
            ("ring_chunk_bytes", Json::num(self.ring_chunk_bytes as f64)),
        ])
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(self.flush_bps > 0.0, "memory.flush_bps must be > 0");
        anyhow::ensure!(self.acp_penalty_bps > 0.0, "memory.acp_penalty_bps must be > 0");
        anyhow::ensure!(
            self.acp_cpu_derate > 0.0 && self.acp_cpu_derate <= 1.0,
            "memory.acp_cpu_derate must be in (0, 1]"
        );
        anyhow::ensure!(self.ring_chunk_bytes > 0, "memory.ring_chunk_bytes must be > 0");
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_copy_through_and_valid() {
        let cfg = MemoryConfig::default();
        assert!(!cfg.is_zero_copy());
        assert_eq!(cfg.port, DmaPortKind::Hp);
        assert_eq!(cfg.mode_label(), "copy");
        cfg.validate().unwrap();
    }

    #[test]
    fn json_roundtrip_identity() {
        let mut cfg = MemoryConfig::default();
        cfg.path = MemoryPath::ZeroCopy;
        cfg.port = DmaPortKind::Acp;
        cfg.flush_bps = 1e9;
        let json = cfg.to_json();
        let mut back = MemoryConfig::default();
        back.apply_json(&json).unwrap();
        assert_eq!(cfg, back);
        assert_eq!(json.get("path").as_str(), Some("zero"));
        assert_eq!(json.get("port").as_str(), Some("acp"));
        assert_eq!(cfg.mode_label(), "zero-acp");
    }

    #[test]
    fn unknown_and_junk_keys_rejected() {
        let mut cfg = MemoryConfig::default();
        assert!(cfg.apply_json(&Json::parse(r#"{"paht": "zero"}"#).unwrap()).is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"path": "dma"}"#).unwrap()).is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"port": "gp"}"#).unwrap()).is_err());
        assert!(cfg.apply_json(&Json::parse(r#"{"flush_bps": "fast"}"#).unwrap()).is_err());
    }

    #[test]
    fn validate_rejects_bad_knobs() {
        let mut cfg = MemoryConfig::default();
        cfg.flush_bps = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = MemoryConfig::default();
        cfg.acp_cpu_derate = 1.5;
        assert!(cfg.validate().is_err());
        let mut cfg = MemoryConfig::default();
        cfg.acp_cpu_derate = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = MemoryConfig::default();
        cfg.ring_chunk_bytes = 0;
        assert!(cfg.validate().is_err());
    }
}
