//! PS memory system: DDR3 controller model, contiguous (CMA) buffer
//! allocator, the CPU memcpy cost model, and the zero-copy memory-path
//! configuration (ACP/HP coherency axis).

pub mod buffer;
pub mod copy;
pub mod ddr;
pub mod path;

pub use buffer::{AllocStrategy, CmaAllocator, DmaBuffer, PhysAddr};
pub use copy::{CoherencyModel, CopyKind, CopyModel};
pub use ddr::{DdrController, DdrDir, Requester};
pub use path::{DmaPortKind, MemoryConfig, MemoryPath};
