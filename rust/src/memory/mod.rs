//! PS memory system: DDR3 controller model, contiguous (CMA) buffer
//! allocator, and the CPU memcpy cost model.

pub mod buffer;
pub mod copy;
pub mod ddr;

pub use buffer::{CmaAllocator, DmaBuffer, PhysAddr};
pub use copy::{CopyKind, CopyModel};
pub use ddr::{DdrController, DdrDir, Requester};
