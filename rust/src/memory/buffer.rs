//! Physically contiguous bounce-buffer allocator (CMA model).
//!
//! DMA descriptors address *physical* memory, so the drivers stage data in
//! buffers carved out of a contiguous-memory-area reservation — exactly what
//! the paper's user-level driver gets from `/dev/mem` + `mmap()` and the
//! kernel driver from `dma_alloc_coherent`. The allocator is a first-fit
//! free-list over a fixed region; it exists so the drivers' single- vs
//! double-buffer schemes manage real reservations with real exhaustion
//! behaviour (VGG19's 8 MB-limit ablation trips on this).

/// Physical address within the CMA region (offset from region base).
#[derive(Clone, Copy, PartialEq, Eq, Debug, Hash, PartialOrd, Ord)]
pub struct PhysAddr(pub u64);

/// An allocated physically contiguous buffer.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct DmaBuffer {
    pub addr: PhysAddr,
    pub len: u64,
}

#[derive(Debug, Clone, PartialEq, Eq)]
pub enum AllocError {
    OutOfMemory { requested: u64, largest: u64 },
    ZeroLength,
    BadFree(DmaBuffer),
}

impl std::fmt::Display for AllocError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            AllocError::OutOfMemory { requested, largest } => write!(
                f,
                "CMA exhausted: requested {requested} bytes, largest free block {largest}"
            ),
            AllocError::ZeroLength => write!(f, "zero-length allocation"),
            AllocError::BadFree(b) => {
                write!(f, "buffer {b:?} was not allocated from this pool (double free?)")
            }
        }
    }
}

impl std::error::Error for AllocError {}

/// Free-extent selection policy. First-fit is the historical default;
/// best-fit is opt-in (via [`CmaAllocator::with_strategy`]) for
/// long-lived region workloads where fragmentation matters more than
/// scan cost.
#[derive(Clone, Copy, PartialEq, Eq, Debug, Default)]
pub enum AllocStrategy {
    #[default]
    FirstFit,
    /// Pick the smallest free extent that satisfies the request
    /// (ties broken toward the lower address, since the scan is in
    /// address order and only strictly smaller extents displace the
    /// current pick).
    BestFit,
}

/// First-fit (or opt-in best-fit) free-list allocator with coalescing on
/// free.
pub struct CmaAllocator {
    capacity: u64,
    align: u64,
    strategy: AllocStrategy,
    /// Sorted, non-overlapping, coalesced free extents (addr, len).
    free: Vec<(u64, u64)>,
    /// Live allocations, for double-free/invariant checking.
    live: Vec<DmaBuffer>,
}

impl CmaAllocator {
    /// `capacity` bytes of contiguous reservation; all allocations aligned
    /// to `align` (AXI-DMA requires at least word alignment; Linux CMA
    /// hands out pages).
    pub fn new(capacity: u64, align: u64) -> Self {
        CmaAllocator::with_strategy(capacity, align, AllocStrategy::FirstFit)
    }

    /// [`CmaAllocator::new`] with an explicit fit strategy.
    pub fn with_strategy(capacity: u64, align: u64, strategy: AllocStrategy) -> Self {
        assert!(align.is_power_of_two(), "alignment must be a power of two");
        assert!(capacity > 0 && capacity % align == 0);
        CmaAllocator { capacity, align, strategy, free: vec![(0, capacity)], live: Vec::new() }
    }

    /// Zynq-ish default: 128 MB CMA, 4 KB page alignment.
    pub fn zynq_default() -> Self {
        CmaAllocator::new(128 << 20, 4096)
    }

    fn round_up(&self, n: u64) -> u64 {
        n.div_ceil(self.align) * self.align
    }

    pub fn alloc(&mut self, len: u64) -> Result<DmaBuffer, AllocError> {
        if len == 0 {
            return Err(AllocError::ZeroLength);
        }
        let want = self.round_up(len);
        let mut largest = 0;
        let mut pick: Option<usize> = None;
        for i in 0..self.free.len() {
            let (_, flen) = self.free[i];
            largest = largest.max(flen);
            if flen >= want {
                match self.strategy {
                    AllocStrategy::FirstFit => {
                        pick = Some(i);
                        break;
                    }
                    AllocStrategy::BestFit => {
                        if pick.is_none_or(|p| self.free[p].1 > flen) {
                            pick = Some(i);
                        }
                    }
                }
            }
        }
        if let Some(i) = pick {
            let (addr, flen) = self.free[i];
            if flen == want {
                self.free.remove(i);
            } else {
                self.free[i] = (addr + want, flen - want);
            }
            let buf = DmaBuffer { addr: PhysAddr(addr), len };
            self.live.push(buf);
            return Ok(buf);
        }
        Err(AllocError::OutOfMemory { requested: want, largest })
    }

    pub fn free(&mut self, buf: DmaBuffer) -> Result<(), AllocError> {
        let Some(pos) = self.live.iter().position(|b| *b == buf) else {
            return Err(AllocError::BadFree(buf));
        };
        self.live.swap_remove(pos);
        let addr = buf.addr.0;
        let len = self.round_up(buf.len);
        // Insert sorted and coalesce with neighbours.
        let idx = self.free.partition_point(|&(a, _)| a < addr);
        self.free.insert(idx, (addr, len));
        // Coalesce right then left.
        if idx + 1 < self.free.len() {
            let (a, l) = self.free[idx];
            let (na, nl) = self.free[idx + 1];
            if a + l == na {
                self.free[idx] = (a, l + nl);
                self.free.remove(idx + 1);
            }
        }
        if idx > 0 {
            let (pa, pl) = self.free[idx - 1];
            let (a, l) = self.free[idx];
            if pa + pl == a {
                self.free[idx - 1] = (pa, pl + l);
                self.free.remove(idx);
            }
        }
        Ok(())
    }

    pub fn free_bytes(&self) -> u64 {
        self.free.iter().map(|&(_, l)| l).sum()
    }

    /// Largest single free extent — the biggest contiguous region still
    /// allocatable (the number [`AllocError::OutOfMemory`] reports).
    pub fn largest_free(&self) -> u64 {
        self.free.iter().map(|&(_, l)| l).max().unwrap_or(0)
    }

    /// External fragmentation: `1 - largest_free / free_bytes`. Zero when
    /// the free space is one extent (or exhausted); approaches 1 as the
    /// free space shatters into many small extents.
    pub fn frag_ratio(&self) -> f64 {
        let total = self.free_bytes();
        if total == 0 {
            return 0.0;
        }
        1.0 - self.largest_free() as f64 / total as f64
    }

    pub fn capacity(&self) -> u64 {
        self.capacity
    }

    pub fn live_count(&self) -> usize {
        self.live.len()
    }

    /// Invariant check used by the property tests: free extents sorted,
    /// non-overlapping, coalesced, within capacity, and disjoint from all
    /// live allocations.
    pub fn check_invariants(&self) -> Result<(), String> {
        let mut prev_end = 0u64;
        for (i, &(a, l)) in self.free.iter().enumerate() {
            if l == 0 {
                return Err(format!("empty free extent at {i}"));
            }
            if i > 0 && a < prev_end {
                return Err("free extents overlap or unsorted".into());
            }
            if i > 0 && a == prev_end {
                return Err("adjacent free extents not coalesced".into());
            }
            if a + l > self.capacity {
                return Err("free extent beyond capacity".into());
            }
            prev_end = a + l;
        }
        for b in &self.live {
            let (ba, bl) = (b.addr.0, self.round_up(b.len));
            for &(fa, fl) in &self.free {
                if ba < fa + fl && fa < ba + bl {
                    return Err(format!("live buffer {b:?} overlaps free extent"));
                }
            }
            if ba % self.align != 0 {
                return Err(format!("misaligned live buffer {b:?}"));
            }
        }
        let live_total: u64 = self.live.iter().map(|b| self.round_up(b.len)).sum();
        if live_total + self.free_bytes() != self.capacity {
            return Err("accounting mismatch: live + free != capacity".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn alloc_free_roundtrip() {
        let mut a = CmaAllocator::new(1 << 20, 4096);
        let b1 = a.alloc(5000).unwrap();
        assert_eq!(b1.addr, PhysAddr(0));
        let b2 = a.alloc(4096).unwrap();
        assert_eq!(b2.addr, PhysAddr(8192), "5000 rounds up to 2 pages");
        a.check_invariants().unwrap();
        a.free(b1).unwrap();
        a.check_invariants().unwrap();
        a.free(b2).unwrap();
        assert_eq!(a.free_bytes(), 1 << 20);
        a.check_invariants().unwrap();
    }

    #[test]
    fn coalescing_restores_one_extent() {
        let mut a = CmaAllocator::new(64 * 4096, 4096);
        let bufs: Vec<_> = (0..8).map(|_| a.alloc(4096).unwrap()).collect();
        // Free in an interleaved order to exercise left/right coalescing.
        for i in [1usize, 3, 5, 7, 0, 2, 4, 6] {
            a.free(bufs[i]).unwrap();
            a.check_invariants().unwrap();
        }
        assert_eq!(a.free.len(), 1);
        assert_eq!(a.free_bytes(), 64 * 4096);
    }

    #[test]
    fn out_of_memory_reports_largest_block() {
        let mut a = CmaAllocator::new(8 * 4096, 4096);
        let _b = a.alloc(6 * 4096).unwrap();
        match a.alloc(4 * 4096) {
            Err(AllocError::OutOfMemory { requested, largest }) => {
                assert_eq!(requested, 4 * 4096);
                assert_eq!(largest, 2 * 4096);
            }
            other => panic!("expected OOM, got {other:?}"),
        }
    }

    #[test]
    fn double_free_rejected() {
        let mut a = CmaAllocator::new(1 << 20, 4096);
        let b = a.alloc(100).unwrap();
        a.free(b).unwrap();
        assert!(matches!(a.free(b), Err(AllocError::BadFree(_))));
    }

    #[test]
    fn zero_len_rejected() {
        let mut a = CmaAllocator::new(1 << 20, 4096);
        assert_eq!(a.alloc(0), Err(AllocError::ZeroLength));
    }

    #[test]
    fn first_fit_reuses_gap() {
        let mut a = CmaAllocator::new(16 * 4096, 4096);
        let b1 = a.alloc(4 * 4096).unwrap();
        let _b2 = a.alloc(4 * 4096).unwrap();
        a.free(b1).unwrap();
        let b3 = a.alloc(2 * 4096).unwrap();
        assert_eq!(b3.addr, PhysAddr(0), "first fit takes the front gap");
        a.check_invariants().unwrap();
    }

    /// Carve [2-page gap][live][4-page gap][live][tail]: best-fit must
    /// place a 2-page request in the tight front gap where first-fit
    /// would too, and a 3-page request in the 4-page gap where first-fit
    /// would split the tail.
    fn gapped(strategy: AllocStrategy) -> (CmaAllocator, DmaBuffer, DmaBuffer) {
        let mut a = CmaAllocator::with_strategy(32 * 4096, 4096, strategy);
        let g1 = a.alloc(2 * 4096).unwrap();
        let p1 = a.alloc(4096).unwrap();
        let g2 = a.alloc(4 * 4096).unwrap();
        let p2 = a.alloc(4096).unwrap();
        a.free(g1).unwrap();
        a.free(g2).unwrap();
        a.check_invariants().unwrap();
        (a, p1, p2)
    }

    #[test]
    fn best_fit_picks_tightest_gap() {
        let (mut a, _, _) = gapped(AllocStrategy::BestFit);
        // 1 page fits every extent: best-fit takes the tight 2-page
        // front gap. 3 pages fit the 4-page gap and the tail: best-fit
        // takes the 4-page gap, leaving the tail pristine.
        let small = a.alloc(4096).unwrap();
        assert_eq!(small.addr, PhysAddr(0), "tightest gap is the 2-page front gap");
        let mid = a.alloc(3 * 4096).unwrap();
        assert_eq!(mid.addr, PhysAddr(3 * 4096), "3 pages go to the 4-page gap");
        a.check_invariants().unwrap();

        // First-fit control: the same 3-page request lands in the front
        // region only if it fits — it doesn't — so both go mid/tail in
        // address order.
        let (mut f, _, _) = gapped(AllocStrategy::FirstFit);
        let small = f.alloc(4096).unwrap();
        assert_eq!(small.addr, PhysAddr(0), "first fit also starts at the front");
        f.check_invariants().unwrap();
    }

    #[test]
    fn best_fit_exact_fit_consumes_extent() {
        let (mut a, _, _) = gapped(AllocStrategy::BestFit);
        let exact = a.alloc(4 * 4096).unwrap();
        assert_eq!(exact.addr, PhysAddr(3 * 4096), "exact fit takes the 4-page gap whole");
        a.check_invariants().unwrap();
        // The 2-page gap and the 24-page tail remain.
        assert_eq!(a.largest_free(), 24 * 4096);
    }

    #[test]
    fn frag_stats_track_shattering_and_coalescing() {
        let mut a = CmaAllocator::new(8 * 4096, 4096);
        assert_eq!(a.largest_free(), 8 * 4096);
        assert_eq!(a.frag_ratio(), 0.0, "one extent = no fragmentation");
        let bufs: Vec<_> = (0..8).map(|_| a.alloc(4096).unwrap()).collect();
        assert_eq!(a.largest_free(), 0);
        assert_eq!(a.frag_ratio(), 0.0, "exhausted pool reports zero, not NaN");
        // Free every other page: 4 one-page extents.
        for i in [0usize, 2, 4, 6] {
            a.free(bufs[i]).unwrap();
        }
        assert_eq!(a.largest_free(), 4096);
        assert!((a.frag_ratio() - 0.75).abs() < 1e-12, "4 equal extents -> 1 - 1/4");
        // Free the rest: coalescing restores one extent.
        for i in [1usize, 3, 5, 7] {
            a.free(bufs[i]).unwrap();
        }
        assert_eq!(a.largest_free(), 8 * 4096);
        assert_eq!(a.frag_ratio(), 0.0);
        a.check_invariants().unwrap();
    }

    #[test]
    fn best_fit_alignment_rounding_matches_first_fit() {
        // A 5000-byte request rounds to 2 pages under both strategies,
        // and the invariants (alignment, accounting) hold throughout.
        for strategy in [AllocStrategy::FirstFit, AllocStrategy::BestFit] {
            let mut a = CmaAllocator::with_strategy(16 * 4096, 4096, strategy);
            let b1 = a.alloc(5000).unwrap();
            let b2 = a.alloc(4096).unwrap();
            assert_eq!(b2.addr, PhysAddr(2 * 4096), "{strategy:?}: 5000 rounds to 2 pages");
            a.check_invariants().unwrap();
            a.free(b1).unwrap();
            a.free(b2).unwrap();
            a.check_invariants().unwrap();
            assert_eq!(a.free_bytes(), 16 * 4096);
            assert_eq!(a.frag_ratio(), 0.0);
        }
    }
}
