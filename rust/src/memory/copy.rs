//! CPU memcpy cost model: virtual-space <-> physical-bounce-buffer copies.
//!
//! The paper's three drivers differ in *where and when* they pay this cost:
//!  * user-level drivers `memcpy()` into an **uncached** CMA bounce buffer
//!    mapped through `/dev/mem` (stores bypass L2, ~half the bandwidth);
//!  * the kernel driver's `copy_from_user`/`copy_to_user` runs on cached
//!    kernel mappings (and flushes afterwards, folded into the rate), and
//!    is chunked so it pipelines with the DMA engine.
//!
//! The model: bandwidth depends on whether the working set fits L2, whether
//! the mapping is cached, and whether a DMA transfer is concurrently hitting
//! DDR (contention derating).

use crate::config::SimConfig;
use crate::sim::time::Dur;

/// Which mapping the CPU copies through.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CopyKind {
    /// memcpy to/from an uncached user-mapped CMA buffer (user-level
    /// drivers).
    UserUncached,
    /// copy_{from,to}_user on cached kernel mappings (kernel driver).
    KernelCached,
}

#[derive(Clone, Debug)]
pub struct CopyModel {
    bw_cached_bps: f64,
    bw_ddr_bps: f64,
    cache_threshold: u64,
    dma_contention: f64,
    uncached_factor: f64,
}

impl CopyModel {
    pub fn new(cfg: &SimConfig) -> Self {
        CopyModel {
            bw_cached_bps: cfg.memcpy_bw_cached_bps,
            bw_ddr_bps: cfg.memcpy_bw_ddr_bps,
            cache_threshold: cfg.memcpy_cache_threshold_bytes,
            dma_contention: cfg.memcpy_dma_contention,
            uncached_factor: cfg.uncached_copy_factor,
        }
    }

    /// Effective bandwidth for one copy operation.
    pub fn bandwidth(&self, bytes: u64, kind: CopyKind, dma_active: bool) -> f64 {
        let mut bw = if bytes <= self.cache_threshold {
            self.bw_cached_bps
        } else {
            self.bw_ddr_bps
        };
        if kind == CopyKind::UserUncached {
            // Uncached stores cannot merge in L2; reads stall the pipeline.
            bw *= self.uncached_factor;
        }
        if dma_active {
            bw *= self.dma_contention;
        }
        bw
    }

    /// CPU time to copy `bytes`.
    pub fn copy_time(&self, bytes: u64, kind: CopyKind, dma_active: bool) -> Dur {
        Dur::for_bytes(bytes, self.bandwidth(bytes, kind, dma_active))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CopyModel {
        let mut cfg = SimConfig::default();
        cfg.memcpy_bw_cached_bps = 1e9;
        cfg.memcpy_bw_ddr_bps = 5e8;
        cfg.memcpy_cache_threshold_bytes = 1024;
        cfg.memcpy_dma_contention = 0.5;
        cfg.uncached_copy_factor = 0.5;
        CopyModel::new(&cfg)
    }

    #[test]
    fn small_copies_run_at_cache_speed() {
        let m = model();
        assert_eq!(m.bandwidth(1024, CopyKind::KernelCached, false), 1e9);
        assert_eq!(m.copy_time(1000, CopyKind::KernelCached, false), Dur(1000));
    }

    #[test]
    fn large_copies_degrade_to_ddr_speed() {
        let m = model();
        assert_eq!(m.bandwidth(1025, CopyKind::KernelCached, false), 5e8);
        assert_eq!(m.copy_time(5000, CopyKind::KernelCached, false), Dur(10_000));
    }

    #[test]
    fn uncached_mapping_halves_bandwidth() {
        let m = model();
        assert_eq!(m.bandwidth(100, CopyKind::UserUncached, false), 0.5e9);
    }

    #[test]
    fn dma_contention_stacks_multiplicatively() {
        let m = model();
        // uncached (0.5) * contention (0.5) = 0.25 of cached bw.
        assert_eq!(m.bandwidth(100, CopyKind::UserUncached, true), 0.25e9);
    }

    #[test]
    fn kernel_beats_user_at_every_size() {
        let m = CopyModel::new(&SimConfig::default());
        for bytes in [64u64, 4096, 65536, 1 << 20, 6 << 20] {
            let u = m.copy_time(bytes, CopyKind::UserUncached, true);
            let k = m.copy_time(bytes, CopyKind::KernelCached, true);
            assert!(k <= u, "kernel copy slower than user copy at {bytes}B");
        }
    }

    #[test]
    fn zero_bytes_is_free() {
        let m = model();
        assert_eq!(m.copy_time(0, CopyKind::UserUncached, true), Dur::ZERO);
    }
}
