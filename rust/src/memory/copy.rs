//! CPU memcpy cost model: virtual-space <-> physical-bounce-buffer copies.
//!
//! The paper's three drivers differ in *where and when* they pay this cost:
//!  * user-level drivers `memcpy()` into an **uncached** CMA bounce buffer
//!    mapped through `/dev/mem` (stores bypass L2, ~half the bandwidth);
//!  * the kernel driver's `copy_from_user`/`copy_to_user` runs on cached
//!    kernel mappings (and flushes afterwards, folded into the rate), and
//!    is chunked so it pipelines with the DMA engine.
//!
//! The model: bandwidth depends on whether the working set fits L2, whether
//! the mapping is cached, and whether a DMA transfer is concurrently hitting
//! DDR (contention derating).

use crate::config::SimConfig;
use crate::memory::path::{DmaPortKind, MemoryConfig};
use crate::sim::time::Dur;

/// Which mapping the CPU copies through.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum CopyKind {
    /// memcpy to/from an uncached user-mapped CMA buffer (user-level
    /// drivers).
    UserUncached,
    /// copy_{from,to}_user on cached kernel mappings (kernel driver).
    KernelCached,
}

#[derive(Clone, Debug)]
pub struct CopyModel {
    bw_cached_bps: f64,
    bw_ddr_bps: f64,
    cache_threshold: u64,
    dma_contention: f64,
    uncached_factor: f64,
}

impl CopyModel {
    pub fn new(cfg: &SimConfig) -> Self {
        CopyModel {
            bw_cached_bps: cfg.memcpy_bw_cached_bps,
            bw_ddr_bps: cfg.memcpy_bw_ddr_bps,
            cache_threshold: cfg.memcpy_cache_threshold_bytes,
            dma_contention: cfg.memcpy_dma_contention,
            uncached_factor: cfg.uncached_copy_factor,
        }
    }

    /// Effective bandwidth for one copy operation.
    pub fn bandwidth(&self, bytes: u64, kind: CopyKind, dma_active: bool) -> f64 {
        let mut bw = if bytes <= self.cache_threshold {
            self.bw_cached_bps
        } else {
            self.bw_ddr_bps
        };
        if kind == CopyKind::UserUncached {
            // Uncached stores cannot merge in L2; reads stall the pipeline.
            bw *= self.uncached_factor;
        }
        if dma_active {
            bw *= self.dma_contention;
        }
        bw
    }

    /// CPU time to copy `bytes`.
    pub fn copy_time(&self, bytes: u64, kind: CopyKind, dma_active: bool) -> Dur {
        Dur::for_bytes(bytes, self.bandwidth(bytes, kind, dma_active))
    }
}

/// Cache-coherency cost model of the zero-copy path (the ACP/HP port
/// axis of [`MemoryConfig`]). Copy-through never charges anything here —
/// its staging copies already serialise CPU and DMA views of the data.
///
/// Zero-copy removes the staging memcpy, so coherency must be paid
/// explicitly, per transfer:
///
/// * **HP** — the engine masters a non-coherent port. Before TX the CPU
///   cleans the frame region (dirty lines reach DDR); after RX it
///   invalidates the result region (stale lines dropped). Each op costs
///   a fixed `maintenance_setup_ns` plus `bytes / flush_bps`.
/// * **ACP** — the engine snoops through the SCU: no maintenance ops at
///   all, but every byte pays `1 / acp_penalty_bps` of sharing toll, and
///   concurrent CPU memcpys run derated ([`CoherencyModel::cpu_derate`]).
///
/// With the defaults the per-transfer fixed HP cost amortises as frames
/// grow while the ACP per-byte toll does not, so ACP wins small frames
/// and HP wins large ones — the crossover the `memory-sweep` command
/// sweeps out.
#[derive(Clone, Debug)]
pub struct CoherencyModel {
    zero_copy: bool,
    port: DmaPortKind,
    flush_bps: f64,
    setup: Dur,
    acp_penalty_bps: f64,
    acp_cpu_derate: f64,
}

impl CoherencyModel {
    pub fn new(cfg: &MemoryConfig) -> Self {
        CoherencyModel {
            zero_copy: cfg.is_zero_copy(),
            port: cfg.port,
            flush_bps: cfg.flush_bps,
            setup: Dur(cfg.maintenance_setup_ns),
            acp_penalty_bps: cfg.acp_penalty_bps,
            acp_cpu_derate: cfg.acp_cpu_derate,
        }
    }

    /// Is the zero-copy path (and therefore this model) engaged?
    #[inline]
    pub fn active(&self) -> bool {
        self.zero_copy
    }

    #[inline]
    pub fn port(&self) -> DmaPortKind {
        self.port
    }

    /// One HP cache-maintenance op over `bytes` (clean or invalidate).
    fn maintenance(&self, bytes: u64) -> Dur {
        self.setup + Dur::for_bytes(bytes, self.flush_bps)
    }

    /// ACP snoop toll over `bytes`.
    fn acp_share(&self, bytes: u64) -> Dur {
        Dur::for_bytes(bytes, self.acp_penalty_bps)
    }

    /// CPU cost charged before the engine reads a TX frame in place:
    /// HP cleans the region; ACP pays the snoop toll.
    pub fn tx_cost(&self, bytes: u64) -> Dur {
        if !self.zero_copy {
            return Dur::ZERO;
        }
        match self.port {
            DmaPortKind::Hp => self.maintenance(bytes),
            DmaPortKind::Acp => self.acp_share(bytes),
        }
    }

    /// CPU cost charged before software reads an RX frame in place:
    /// HP invalidates the region; ACP pays the snoop toll.
    pub fn rx_cost(&self, bytes: u64) -> Dur {
        if !self.zero_copy {
            return Dur::ZERO;
        }
        match self.port {
            DmaPortKind::Hp => self.maintenance(bytes),
            DmaPortKind::Acp => self.acp_share(bytes),
        }
    }

    /// Multiplier on CPU memcpy bandwidth while DMA is in flight: below
    /// 1 only on an active ACP path (snoops contend for L2 tags).
    #[inline]
    pub fn cpu_derate(&self) -> f64 {
        if self.zero_copy && self.port == DmaPortKind::Acp {
            self.acp_cpu_derate
        } else {
            1.0
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn model() -> CopyModel {
        let mut cfg = SimConfig::default();
        cfg.memcpy_bw_cached_bps = 1e9;
        cfg.memcpy_bw_ddr_bps = 5e8;
        cfg.memcpy_cache_threshold_bytes = 1024;
        cfg.memcpy_dma_contention = 0.5;
        cfg.uncached_copy_factor = 0.5;
        CopyModel::new(&cfg)
    }

    #[test]
    fn small_copies_run_at_cache_speed() {
        let m = model();
        assert_eq!(m.bandwidth(1024, CopyKind::KernelCached, false), 1e9);
        assert_eq!(m.copy_time(1000, CopyKind::KernelCached, false), Dur(1000));
    }

    #[test]
    fn large_copies_degrade_to_ddr_speed() {
        let m = model();
        assert_eq!(m.bandwidth(1025, CopyKind::KernelCached, false), 5e8);
        assert_eq!(m.copy_time(5000, CopyKind::KernelCached, false), Dur(10_000));
    }

    #[test]
    fn uncached_mapping_halves_bandwidth() {
        let m = model();
        assert_eq!(m.bandwidth(100, CopyKind::UserUncached, false), 0.5e9);
    }

    #[test]
    fn dma_contention_stacks_multiplicatively() {
        let m = model();
        // uncached (0.5) * contention (0.5) = 0.25 of cached bw.
        assert_eq!(m.bandwidth(100, CopyKind::UserUncached, true), 0.25e9);
    }

    #[test]
    fn kernel_beats_user_at_every_size() {
        let m = CopyModel::new(&SimConfig::default());
        for bytes in [64u64, 4096, 65536, 1 << 20, 6 << 20] {
            let u = m.copy_time(bytes, CopyKind::UserUncached, true);
            let k = m.copy_time(bytes, CopyKind::KernelCached, true);
            assert!(k <= u, "kernel copy slower than user copy at {bytes}B");
        }
    }

    #[test]
    fn zero_bytes_is_free() {
        let m = model();
        assert_eq!(m.copy_time(0, CopyKind::UserUncached, true), Dur::ZERO);
    }

    fn coh(path: crate::memory::path::MemoryPath, port: DmaPortKind) -> CoherencyModel {
        let mut c = MemoryConfig::default();
        c.path = path;
        c.port = port;
        CoherencyModel::new(&c)
    }

    #[test]
    fn copy_through_coherency_is_free() {
        use crate::memory::path::MemoryPath;
        for port in [DmaPortKind::Hp, DmaPortKind::Acp] {
            let m = coh(MemoryPath::CopyThrough, port);
            assert!(!m.active());
            assert_eq!(m.tx_cost(1 << 20), Dur::ZERO);
            assert_eq!(m.rx_cost(1 << 20), Dur::ZERO);
            assert_eq!(m.cpu_derate(), 1.0);
        }
    }

    #[test]
    fn hp_charges_setup_plus_line_walk() {
        use crate::memory::path::MemoryPath;
        let cfg = MemoryConfig::default();
        let m = coh(MemoryPath::ZeroCopy, DmaPortKind::Hp);
        let bytes = 1 << 20;
        let expect = Dur(cfg.maintenance_setup_ns) + Dur::for_bytes(bytes, cfg.flush_bps);
        assert_eq!(m.tx_cost(bytes), expect);
        assert_eq!(m.rx_cost(bytes), expect);
        assert_eq!(m.cpu_derate(), 1.0, "HP does not snoop the L2");
    }

    #[test]
    fn acp_charges_per_byte_only_and_derates_cpu() {
        use crate::memory::path::MemoryPath;
        let cfg = MemoryConfig::default();
        let m = coh(MemoryPath::ZeroCopy, DmaPortKind::Acp);
        let bytes = 1 << 20;
        assert_eq!(m.tx_cost(bytes), Dur::for_bytes(bytes, cfg.acp_penalty_bps));
        assert_eq!(m.cpu_derate(), cfg.acp_cpu_derate);
    }

    /// The defaults must place the ACP/HP crossover between the smallest
    /// and largest swept frame sizes: ACP's per-byte toll wins small
    /// frames (no fixed maintenance setup), HP's amortised fixed cost
    /// wins large ones.
    #[test]
    fn acp_wins_small_hp_wins_large() {
        use crate::memory::path::MemoryPath;
        let hp = coh(MemoryPath::ZeroCopy, DmaPortKind::Hp);
        let acp = coh(MemoryPath::ZeroCopy, DmaPortKind::Acp);
        let total = |m: &CoherencyModel, b: u64| m.tx_cost(b) + m.rx_cost(b);
        assert!(total(&acp, 4 << 10) < total(&hp, 4 << 10), "ACP must win at 4KB");
        assert!(total(&hp, 64 << 10) < total(&acp, 64 << 10), "HP must win at 64KB");
    }
}
