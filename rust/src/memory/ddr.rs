//! DDR3 controller + AXI HP port arbitration model.
//!
//! A single-served-burst controller: requests from the DMA channels (and
//! optionally a background CPU stream) queue per requester; the arbiter
//! grants one burst at a time in fixed priority order MM2S > S2MM > CPU.
//! Service time = fixed latency + optional read/write turnaround +
//! bytes / bandwidth.
//!
//! With multiple AXI-DMA engines, each priority class holds one subqueue
//! per engine and grants rotate between engines **deficit-weighted
//! round-robin** (`SimConfig::ddr_engine_weights`): an engine with weight
//! *w* receives *w* grants per refill round while it has work queued. A
//! single engine degenerates exactly to the seed's fixed-priority
//! behaviour, which keeps the golden single-channel timings bit-identical.
//!
//! Two paper phenomena live here:
//!  * "DDR memory cannot attend read and write operations at the same
//!    time" — a loop-back run keeps both channels queued, and the
//!    turnaround penalty is paid on every alternation;
//!  * TX priority over RX — MM2S is granted first, which is why the
//!    paper's TX latencies sit below RX at every size (Fig. 4/5).

use std::collections::VecDeque;

use crate::config::SimConfig;
use crate::sim::engine::Engine;
use crate::sim::event::{DdrReqId, EngineId, Event};
use crate::sim::time::Dur;

/// Direction of a DDR access (from the controller's point of view).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum DdrDir {
    Read,
    Write,
}

/// Who issued the burst. The two DMA classes carry the owning engine so
/// the dispatcher can route completions; classes are in fixed priority
/// order (highest first).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Requester {
    /// MM2S descriptor/data reads (the TX path) of one engine.
    Mm2s(EngineId),
    /// S2MM data writes (the RX path) of one engine.
    S2mm(EngineId),
    /// Background CPU traffic (memcpy spill, other processes).
    Cpu,
}

impl Requester {
    /// Priority class index: MM2S(any) = 0, S2MM(any) = 1, CPU = 2.
    #[inline]
    pub fn class(self) -> usize {
        match self {
            Requester::Mm2s(_) => 0,
            Requester::S2mm(_) => 1,
            Requester::Cpu => 2,
        }
    }

    /// The owning engine, for the DMA classes.
    #[inline]
    pub fn engine(self) -> Option<EngineId> {
        match self {
            Requester::Mm2s(e) | Requester::S2mm(e) => Some(e),
            Requester::Cpu => None,
        }
    }
}

#[derive(Clone, Copy, Debug)]
pub struct DdrRequest {
    pub id: DdrReqId,
    pub dir: DdrDir,
    pub bytes: u64,
    pub requester: Requester,
}

/// Completion notification returned to the dispatcher.
#[derive(Clone, Copy, Debug)]
pub struct DdrCompletion {
    pub id: DdrReqId,
    pub requester: Requester,
    pub dir: DdrDir,
    pub bytes: u64,
    /// When the burst was granted (service start) — for trace export.
    pub started_at: crate::sim::time::SimTime,
}

/// Aggregate controller statistics (per simulation run).
#[derive(Clone, Debug, Default)]
pub struct DdrStats {
    pub bursts: u64,
    pub bytes: u64,
    /// Served bytes split by priority class (index = MM2S/S2MM/CPU,
    /// summed over engines) — how much each port class actually got.
    /// Under saturation the CPU row shows the starvation that
    /// fixed-priority arbitration inflicts on background processes.
    pub bytes_by: [u64; 3],
    /// Served bytes per engine, split MM2S/S2MM — the per-channel share
    /// the scaling experiments report.
    pub bytes_by_engine: Vec<[u64; 2]>,
    pub turnarounds: u64,
    pub busy_ns: u64,
}

/// One priority class of DMA traffic: a subqueue per engine plus the
/// deficit-round-robin grant state.
#[derive(Clone)]
struct DmaClass {
    queues: Vec<VecDeque<DdrRequest>>,
    /// Remaining grants this refill round, per engine.
    credit: Vec<u64>,
    /// Engine index to scan from on the next grant (rotates for fairness
    /// among equal weights).
    cursor: usize,
}

impl DmaClass {
    fn new(n: usize, weights: &[u64]) -> Self {
        DmaClass {
            queues: (0..n).map(|_| VecDeque::new()).collect(),
            credit: (0..n).map(|i| weight_of(weights, i)).collect(),
            cursor: 0,
        }
    }

    fn is_empty(&self) -> bool {
        self.queues.iter().all(VecDeque::is_empty)
    }

    /// Pick the next engine to serve: scan from the cursor for a
    /// non-empty queue with credit left; if every non-empty queue is out
    /// of credit, refill all credits and scan again. Deterministic, and
    /// with one engine it always picks queue 0 immediately.
    fn grant(&mut self, weights: &[u64]) -> Option<DdrRequest> {
        let n = self.queues.len();
        if self.is_empty() {
            return None;
        }
        for round in 0..2 {
            if round == 1 {
                for (i, c) in self.credit.iter_mut().enumerate() {
                    *c = weight_of(weights, i);
                }
            }
            for off in 0..n {
                let i = (self.cursor + off) % n;
                if !self.queues[i].is_empty() && self.credit[i] > 0 {
                    self.credit[i] -= 1;
                    // Keep serving this engine while its credit lasts;
                    // move the cursor only when its credit is spent.
                    if self.credit[i] == 0 {
                        self.cursor = (i + 1) % n;
                    } else {
                        self.cursor = i;
                    }
                    return self.queues[i].pop_front();
                }
            }
        }
        unreachable!("non-empty class must grant after a credit refill")
    }
}

#[inline]
fn weight_of(weights: &[u64], engine: usize) -> u64 {
    // Engines beyond the configured list inherit the last weight (a
    // single-element list means "all equal").
    weights
        .get(engine)
        .or(weights.last())
        .copied()
        .unwrap_or(1)
        .max(1)
}

#[derive(Clone)]
pub struct DdrController {
    /// Reciprocal bandwidth in ns/byte (service time is a hot-path
    /// multiply, not a divide — §Perf).
    ns_per_byte: f64,
    latency: Dur,
    turnaround: Dur,
    /// Per-engine arbitration weights (see `SimConfig::ddr_engine_weights`).
    weights: Vec<u64>,
    mm2s: DmaClass,
    s2mm: DmaClass,
    cpu: VecDeque<DdrRequest>,
    in_flight: Option<(DdrRequest, crate::sim::time::SimTime)>,
    last_dir: Option<DdrDir>,
    next_id: u64,
    /// Service-time multiplier >= 1; raised while the CPU spins on the DMA
    /// status register (see `SimConfig::polling_dma_penalty`).
    pub contention_factor: f64,
    /// Fault-injection hook: extra service-time multiplier applied while
    /// the simulated clock is before `fault_until` (a modelled burst of
    /// DDR contention from other masters). Composes multiplicatively
    /// with `contention_factor`. See [`DdrController::set_fault_window`].
    fault_factor: f64,
    fault_until: crate::sim::time::SimTime,
    pub stats: DdrStats,
}

impl DdrController {
    pub fn new(cfg: &SimConfig) -> Self {
        let n = cfg.num_engines as usize;
        DdrController {
            ns_per_byte: 1e9 / cfg.ddr_bandwidth_bps,
            latency: Dur(cfg.ddr_latency_ns),
            turnaround: Dur(cfg.ddr_turnaround_ns),
            weights: cfg.ddr_engine_weights.clone(),
            mm2s: DmaClass::new(n, &cfg.ddr_engine_weights),
            s2mm: DmaClass::new(n, &cfg.ddr_engine_weights),
            cpu: VecDeque::new(),
            in_flight: None,
            last_dir: None,
            next_id: 0,
            contention_factor: 1.0,
            fault_factor: 1.0,
            fault_until: crate::sim::time::SimTime::ZERO,
            stats: DdrStats { bytes_by_engine: vec![[0; 2]; n], ..DdrStats::default() },
        }
    }

    /// Open a contention window: bursts granted before `until` are served
    /// `factor`× slower (fault-injection hook; see [`crate::sim::fault`]).
    pub fn set_fault_window(&mut self, factor: f64, until: crate::sim::time::SimTime) {
        debug_assert!(factor >= 1.0);
        self.fault_factor = factor;
        self.fault_until = until;
    }

    /// Enqueue a burst and poke the arbiter.
    pub fn submit(
        &mut self,
        eng: &mut Engine,
        dir: DdrDir,
        bytes: u64,
        requester: Requester,
    ) -> DdrReqId {
        assert!(bytes > 0, "zero-byte DDR burst");
        let id = DdrReqId(self.next_id);
        self.next_id += 1;
        let req = DdrRequest { id, dir, bytes, requester };
        match requester {
            Requester::Mm2s(e) => self.mm2s.queues[e.index()].push_back(req),
            Requester::S2mm(e) => self.s2mm.queues[e.index()].push_back(req),
            Requester::Cpu => self.cpu.push_back(req),
        }
        // Poke the arbiter only when it could actually grant: while a
        // burst is in flight, the completion path re-issues anyway
        // (§Perf: this removes ~1 calendar event per burst).
        if self.in_flight.is_none() {
            eng.schedule_now(Event::DdrIssue);
        }
        id
    }

    /// Arbiter step (handles `Event::DdrIssue`): grant the highest-priority
    /// queued burst if the data bus is free. Within the MM2S and S2MM
    /// classes the engines share by weighted round-robin.
    pub fn issue(&mut self, eng: &mut Engine) {
        if self.in_flight.is_some() {
            return;
        }
        let req = if !self.mm2s.is_empty() {
            self.mm2s.grant(&self.weights)
        } else if !self.s2mm.is_empty() {
            self.s2mm.grant(&self.weights)
        } else {
            self.cpu.pop_front()
        };
        let Some(req) = req else { return };

        let mut service =
            self.latency + Dur((req.bytes as f64 * self.ns_per_byte).ceil() as u64);
        if let Some(last) = self.last_dir {
            if last != req.dir {
                service += self.turnaround;
                self.stats.turnarounds += 1;
            }
        }
        let mut factor = self.contention_factor;
        if eng.now() < self.fault_until {
            factor *= self.fault_factor;
        }
        if factor > 1.0 {
            service = service.scaled(factor);
        }
        self.last_dir = Some(req.dir);
        self.stats.bursts += 1;
        self.stats.bytes += req.bytes;
        let class = req.requester.class();
        self.stats.bytes_by[class] += req.bytes;
        if let Some(e) = req.requester.engine() {
            self.stats.bytes_by_engine[e.index()][class] += req.bytes;
        }
        self.stats.busy_ns += service.ns();
        self.in_flight = Some((req, eng.now()));
        eng.schedule(service, Event::DdrDone { req: req.id });
    }

    /// Completion step (handles `Event::DdrDone`). Returns the finished
    /// request so the dispatcher can notify the owning channel, and pokes
    /// the arbiter for the next grant.
    pub fn complete(&mut self, eng: &mut Engine, id: DdrReqId) -> DdrCompletion {
        let (req, started_at) = self
            .in_flight
            .take()
            .expect("DdrDone with no burst in flight");
        assert_eq!(req.id, id, "DdrDone for a request that is not in flight");
        // Re-arm the arbiter only if work is queued; a submit arriving
        // later finds the bus idle and pokes it itself.
        if self.pending_requests().next().is_some() {
            eng.schedule_now(Event::DdrIssue);
        }
        DdrCompletion {
            id: req.id,
            requester: req.requester,
            dir: req.dir,
            bytes: req.bytes,
            started_at,
        }
    }

    /// Every request awaiting grant, drained lazily in class-priority
    /// order (MM2S engines, S2MM engines, CPU) without allocating — the
    /// view behind the arbiter's emptiness checks and the blocked-
    /// transfer diagnostic's [`DdrController::backlog_bytes`].
    pub fn pending_requests(&self) -> impl Iterator<Item = &DdrRequest> + '_ {
        self.mm2s
            .queues
            .iter()
            .chain(self.s2mm.queues.iter())
            .flat_map(|q| q.iter())
            .chain(self.cpu.iter())
    }

    /// Total queued (not yet granted) bytes — reported by
    /// [`crate::system::SimError::Blocked`].
    pub fn backlog_bytes(&self) -> u64 {
        self.pending_requests().map(|r| r.bytes).sum()
    }

    pub fn is_idle(&self) -> bool {
        self.in_flight.is_none() && self.pending_requests().next().is_none()
    }

    pub fn queued(&self, r: Requester) -> usize {
        match r {
            Requester::Mm2s(e) => self.mm2s.queues[e.index()].len(),
            Requester::S2mm(e) => self.s2mm.queues[e.index()].len(),
            Requester::Cpu => self.cpu.len(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::time::SimTime;

    const E0: EngineId = EngineId(0);
    const E1: EngineId = EngineId(1);

    fn drive(ddr: &mut DdrController, eng: &mut Engine) -> Vec<(SimTime, DdrCompletion)> {
        let mut done = Vec::new();
        while let Some((t, ev)) = eng.pop() {
            match ev {
                Event::DdrIssue => ddr.issue(eng),
                Event::DdrDone { req } => done.push((t, ddr.complete(eng, req))),
                other => panic!("unexpected event {other:?}"),
            }
        }
        done
    }

    fn cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.ddr_bandwidth_bps = 1e9; // 1 B/ns: easy arithmetic
        c.ddr_latency_ns = 100;
        c.ddr_turnaround_ns = 50;
        c
    }

    fn cfg_engines(n: u64) -> SimConfig {
        let mut c = cfg();
        c.num_engines = n;
        c
    }

    #[test]
    fn single_burst_timing() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        ddr.submit(&mut eng, DdrDir::Read, 1000, Requester::Mm2s(E0));
        let done = drive(&mut ddr, &mut eng);
        assert_eq!(done.len(), 1);
        // latency 100 + 1000B @ 1B/ns = 1100 ns; no turnaround on first burst.
        assert_eq!(done[0].0, SimTime(1100));
        assert!(ddr.is_idle());
    }

    #[test]
    fn mm2s_has_priority_over_s2mm() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        // Submit S2MM first, then MM2S at the same instant: MM2S must win
        // arbitration... but only for grants while both are *queued*. The
        // first DdrIssue fires before the MM2S submit exists, so seed both
        // before driving.
        ddr.submit(&mut eng, DdrDir::Write, 100, Requester::S2mm(E0));
        ddr.submit(&mut eng, DdrDir::Read, 100, Requester::Mm2s(E0));
        let done = drive(&mut ddr, &mut eng);
        assert_eq!(done[0].1.requester, Requester::Mm2s(E0), "TX priority");
        assert_eq!(done[1].1.requester, Requester::S2mm(E0));
    }

    #[test]
    fn turnaround_charged_on_direction_change() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        ddr.submit(&mut eng, DdrDir::Read, 100, Requester::Mm2s(E0));
        ddr.submit(&mut eng, DdrDir::Write, 100, Requester::S2mm(E0));
        ddr.submit(&mut eng, DdrDir::Write, 100, Requester::S2mm(E0));
        let done = drive(&mut ddr, &mut eng);
        // Burst 1: 100+100 = 200. Burst 2: +50 turnaround = 250. Burst 3:
        // same direction = 200.
        assert_eq!(done[0].0, SimTime(200));
        assert_eq!(done[1].0, SimTime(450));
        assert_eq!(done[2].0, SimTime(650));
        assert_eq!(ddr.stats.turnarounds, 1);
        assert_eq!(ddr.stats.bursts, 3);
        assert_eq!(ddr.stats.bytes, 300);
    }

    #[test]
    fn contention_factor_slows_service() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        ddr.contention_factor = 2.0;
        ddr.submit(&mut eng, DdrDir::Read, 1000, Requester::Mm2s(E0));
        let done = drive(&mut ddr, &mut eng);
        assert_eq!(done[0].0, SimTime(2200));
    }

    #[test]
    fn fifo_within_one_requester() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        let a = ddr.submit(&mut eng, DdrDir::Read, 8, Requester::Mm2s(E0));
        let b = ddr.submit(&mut eng, DdrDir::Read, 8, Requester::Mm2s(E0));
        let done = drive(&mut ddr, &mut eng);
        assert_eq!(done[0].1.id, a);
        assert_eq!(done[1].1.id, b);
    }

    #[test]
    fn equal_weights_interleave_engines() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg_engines(2));
        // Four reads queued on each engine before driving: grants must
        // alternate engine 0 / engine 1 (weight 1 each).
        for _ in 0..4 {
            ddr.submit(&mut eng, DdrDir::Read, 8, Requester::Mm2s(E0));
            ddr.submit(&mut eng, DdrDir::Read, 8, Requester::Mm2s(E1));
        }
        let done = drive(&mut ddr, &mut eng);
        let engines: Vec<u8> =
            done.iter().map(|(_, c)| c.requester.engine().unwrap().0).collect();
        assert_eq!(engines, vec![0, 1, 0, 1, 0, 1, 0, 1], "round-robin violated");
        assert_eq!(ddr.stats.bytes_by_engine[0][0], 32);
        assert_eq!(ddr.stats.bytes_by_engine[1][0], 32);
    }

    #[test]
    fn weights_skew_grant_shares() {
        let mut eng = Engine::new();
        let mut c = cfg_engines(2);
        c.ddr_engine_weights = vec![3, 1];
        let mut ddr = DdrController::new(&c);
        for _ in 0..8 {
            ddr.submit(&mut eng, DdrDir::Read, 8, Requester::Mm2s(E0));
            ddr.submit(&mut eng, DdrDir::Read, 8, Requester::Mm2s(E1));
        }
        let done = drive(&mut ddr, &mut eng);
        // First 8 grants: engine 0 gets 3 for every 1 of engine 1.
        let first8: Vec<u8> =
            done.iter().take(8).map(|(_, c)| c.requester.engine().unwrap().0).collect();
        assert_eq!(first8.iter().filter(|&&e| e == 0).count(), 6, "{first8:?}");
    }

    #[test]
    fn weighted_engine_does_not_starve_the_other() {
        let mut eng = Engine::new();
        let mut c = cfg_engines(2);
        c.ddr_engine_weights = vec![4, 1];
        let mut ddr = DdrController::new(&c);
        for _ in 0..10 {
            ddr.submit(&mut eng, DdrDir::Read, 8, Requester::Mm2s(E0));
        }
        ddr.submit(&mut eng, DdrDir::Read, 8, Requester::Mm2s(E1));
        let done = drive(&mut ddr, &mut eng);
        let pos = done
            .iter()
            .position(|(_, c)| c.requester.engine() == Some(E1))
            .expect("engine 1 must be served");
        assert!(pos <= 8, "engine 1 starved until grant {pos}");
    }

    #[test]
    fn fault_window_slows_service_until_expiry() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        ddr.set_fault_window(3.0, SimTime(500));
        // Granted at t=0, inside the window: (100 + 100) × 3 = 600 ns.
        ddr.submit(&mut eng, DdrDir::Read, 100, Requester::Mm2s(E0));
        let done = drive(&mut ddr, &mut eng);
        assert_eq!(done[0].0, SimTime(600));
        // Granted at t=600, past the window: normal 200 ns service.
        ddr.submit(&mut eng, DdrDir::Read, 100, Requester::Mm2s(E0));
        let done = drive(&mut ddr, &mut eng);
        assert_eq!(done[0].0, SimTime(800));
    }

    #[test]
    #[should_panic(expected = "zero-byte")]
    fn zero_byte_burst_rejected() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        ddr.submit(&mut eng, DdrDir::Read, 0, Requester::Mm2s(E0));
    }

    #[test]
    fn pending_iterator_drains_in_priority_order() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg_engines(2));
        ddr.submit(&mut eng, DdrDir::Write, 1, Requester::Cpu);
        ddr.submit(&mut eng, DdrDir::Write, 2, Requester::S2mm(E1));
        ddr.submit(&mut eng, DdrDir::Read, 4, Requester::Mm2s(E0));
        ddr.submit(&mut eng, DdrDir::Read, 8, Requester::Mm2s(E1));
        let order: Vec<u64> = ddr.pending_requests().map(|r| r.bytes).collect();
        // MM2S engine 0, MM2S engine 1, S2MM engine 1, CPU.
        assert_eq!(order, vec![4, 8, 2, 1]);
        assert_eq!(ddr.backlog_bytes(), 15);
        assert!(!ddr.is_idle());
        drive(&mut ddr, &mut eng);
        assert_eq!(ddr.backlog_bytes(), 0);
        assert!(ddr.is_idle());
        assert_eq!(ddr.pending_requests().count(), 0);
    }
}
