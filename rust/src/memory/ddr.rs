//! DDR3 controller + AXI HP port arbitration model.
//!
//! A single-served-burst controller: requests from the DMA channels (and
//! optionally a background CPU stream) queue per requester; the arbiter
//! grants one burst at a time in fixed priority order MM2S > S2MM > CPU.
//! Service time = fixed latency + optional read/write turnaround +
//! bytes / bandwidth.
//!
//! Two paper phenomena live here:
//!  * "DDR memory cannot attend read and write operations at the same
//!    time" — a loop-back run keeps both channels queued, and the
//!    turnaround penalty is paid on every alternation;
//!  * TX priority over RX — MM2S is granted first, which is why the
//!    paper's TX latencies sit below RX at every size (Fig. 4/5).

use std::collections::VecDeque;

use crate::config::SimConfig;
use crate::sim::engine::Engine;
use crate::sim::event::{DdrReqId, Event};
use crate::sim::time::Dur;

/// Direction of a DDR access (from the controller's point of view).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum DdrDir {
    Read,
    Write,
}

/// Who issued the burst. Declared in fixed priority order (highest first);
/// `ALL` below relies on this.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum Requester {
    /// MM2S descriptor/data reads (the TX path).
    Mm2s,
    /// S2MM data writes (the RX path).
    S2mm,
    /// Background CPU traffic (memcpy spill, other processes).
    Cpu,
}

const ALL: [Requester; 3] = [Requester::Mm2s, Requester::S2mm, Requester::Cpu];

#[derive(Clone, Copy, Debug)]
pub struct DdrRequest {
    pub id: DdrReqId,
    pub dir: DdrDir,
    pub bytes: u64,
    pub requester: Requester,
}

/// Completion notification returned to the dispatcher.
#[derive(Clone, Copy, Debug)]
pub struct DdrCompletion {
    pub id: DdrReqId,
    pub requester: Requester,
    pub dir: DdrDir,
    pub bytes: u64,
    /// When the burst was granted (service start) — for trace export.
    pub started_at: crate::sim::time::SimTime,
}

/// Aggregate controller statistics (per simulation run).
#[derive(Clone, Copy, Debug, Default)]
pub struct DdrStats {
    pub bursts: u64,
    pub bytes: u64,
    /// Served bytes split by requester (index = priority order
    /// MM2S/S2MM/CPU) — how much each port actually got. Under
    /// saturation the CPU row shows the starvation that fixed-priority
    /// arbitration inflicts on background processes.
    pub bytes_by: [u64; 3],
    pub turnarounds: u64,
    pub busy_ns: u64,
}

pub struct DdrController {
    /// Reciprocal bandwidth in ns/byte (service time is a hot-path
    /// multiply, not a divide — §Perf).
    ns_per_byte: f64,
    latency: Dur,
    turnaround: Dur,
    queues: [VecDeque<DdrRequest>; 3],
    in_flight: Option<(DdrRequest, crate::sim::time::SimTime)>,
    last_dir: Option<DdrDir>,
    next_id: u64,
    /// Service-time multiplier >= 1; raised while the CPU spins on the DMA
    /// status register (see `SimConfig::polling_dma_penalty`).
    pub contention_factor: f64,
    pub stats: DdrStats,
}

impl DdrController {
    pub fn new(cfg: &SimConfig) -> Self {
        DdrController {
            ns_per_byte: 1e9 / cfg.ddr_bandwidth_bps,
            latency: Dur(cfg.ddr_latency_ns),
            turnaround: Dur(cfg.ddr_turnaround_ns),
            queues: [VecDeque::new(), VecDeque::new(), VecDeque::new()],
            in_flight: None,
            last_dir: None,
            next_id: 0,
            contention_factor: 1.0,
            stats: DdrStats::default(),
        }
    }

    fn queue_index(r: Requester) -> usize {
        ALL.iter().position(|&x| x == r).unwrap()
    }

    /// Enqueue a burst and poke the arbiter.
    pub fn submit(
        &mut self,
        eng: &mut Engine,
        dir: DdrDir,
        bytes: u64,
        requester: Requester,
    ) -> DdrReqId {
        assert!(bytes > 0, "zero-byte DDR burst");
        let id = DdrReqId(self.next_id);
        self.next_id += 1;
        self.queues[Self::queue_index(requester)].push_back(DdrRequest {
            id,
            dir,
            bytes,
            requester,
        });
        // Poke the arbiter only when it could actually grant: while a
        // burst is in flight, the completion path re-issues anyway
        // (§Perf: this removes ~1 calendar event per burst).
        if self.in_flight.is_none() {
            eng.schedule_now(Event::DdrIssue);
        }
        id
    }

    /// Arbiter step (handles `Event::DdrIssue`): grant the highest-priority
    /// queued burst if the data bus is free.
    pub fn issue(&mut self, eng: &mut Engine) {
        if self.in_flight.is_some() {
            return;
        }
        let Some(req) = ALL
            .iter()
            .find_map(|&r| {
                let q = &mut self.queues[Self::queue_index(r)];
                if q.is_empty() {
                    None
                } else {
                    q.pop_front()
                }
            })
        else {
            return;
        };

        let mut service =
            self.latency + Dur((req.bytes as f64 * self.ns_per_byte).ceil() as u64);
        if let Some(last) = self.last_dir {
            if last != req.dir {
                service += self.turnaround;
                self.stats.turnarounds += 1;
            }
        }
        if self.contention_factor > 1.0 {
            service = service.scaled(self.contention_factor);
        }
        self.last_dir = Some(req.dir);
        self.stats.bursts += 1;
        self.stats.bytes += req.bytes;
        self.stats.bytes_by[Self::queue_index(req.requester)] += req.bytes;
        self.stats.busy_ns += service.ns();
        self.in_flight = Some((req, eng.now()));
        eng.schedule(service, Event::DdrDone { req: req.id });
    }

    /// Completion step (handles `Event::DdrDone`). Returns the finished
    /// request so the dispatcher can notify the owning channel, and pokes
    /// the arbiter for the next grant.
    pub fn complete(&mut self, eng: &mut Engine, id: DdrReqId) -> DdrCompletion {
        let (req, started_at) = self
            .in_flight
            .take()
            .expect("DdrDone with no burst in flight");
        assert_eq!(req.id, id, "DdrDone for a request that is not in flight");
        // Re-arm the arbiter only if work is queued; a submit arriving
        // later finds the bus idle and pokes it itself.
        if !self.queues.iter().all(VecDeque::is_empty) {
            eng.schedule_now(Event::DdrIssue);
        }
        DdrCompletion {
            id: req.id,
            requester: req.requester,
            dir: req.dir,
            bytes: req.bytes,
            started_at,
        }
    }

    pub fn is_idle(&self) -> bool {
        self.in_flight.is_none() && self.queues.iter().all(VecDeque::is_empty)
    }

    pub fn queued(&self, r: Requester) -> usize {
        self.queues[Self::queue_index(r)].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::time::SimTime;

    fn drive(ddr: &mut DdrController, eng: &mut Engine) -> Vec<(SimTime, DdrCompletion)> {
        let mut done = Vec::new();
        while let Some((t, ev)) = eng.pop() {
            match ev {
                Event::DdrIssue => ddr.issue(eng),
                Event::DdrDone { req } => done.push((t, ddr.complete(eng, req))),
                other => panic!("unexpected event {other:?}"),
            }
        }
        done
    }

    fn cfg() -> SimConfig {
        let mut c = SimConfig::default();
        c.ddr_bandwidth_bps = 1e9; // 1 B/ns: easy arithmetic
        c.ddr_latency_ns = 100;
        c.ddr_turnaround_ns = 50;
        c
    }

    #[test]
    fn single_burst_timing() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        ddr.submit(&mut eng, DdrDir::Read, 1000, Requester::Mm2s);
        let done = drive(&mut ddr, &mut eng);
        assert_eq!(done.len(), 1);
        // latency 100 + 1000B @ 1B/ns = 1100 ns; no turnaround on first burst.
        assert_eq!(done[0].0, SimTime(1100));
        assert!(ddr.is_idle());
    }

    #[test]
    fn mm2s_has_priority_over_s2mm() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        // Submit S2MM first, then MM2S at the same instant: MM2S must win
        // arbitration... but only for grants while both are *queued*. The
        // first DdrIssue fires before the MM2S submit exists, so seed both
        // before driving.
        ddr.submit(&mut eng, DdrDir::Write, 100, Requester::S2mm);
        ddr.submit(&mut eng, DdrDir::Read, 100, Requester::Mm2s);
        let done = drive(&mut ddr, &mut eng);
        assert_eq!(done[0].1.requester, Requester::Mm2s, "TX priority");
        assert_eq!(done[1].1.requester, Requester::S2mm);
    }

    #[test]
    fn turnaround_charged_on_direction_change() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        ddr.submit(&mut eng, DdrDir::Read, 100, Requester::Mm2s);
        ddr.submit(&mut eng, DdrDir::Write, 100, Requester::S2mm);
        ddr.submit(&mut eng, DdrDir::Write, 100, Requester::S2mm);
        let done = drive(&mut ddr, &mut eng);
        // Burst 1: 100+100 = 200. Burst 2: +50 turnaround = 250. Burst 3:
        // same direction = 200.
        assert_eq!(done[0].0, SimTime(200));
        assert_eq!(done[1].0, SimTime(450));
        assert_eq!(done[2].0, SimTime(650));
        assert_eq!(ddr.stats.turnarounds, 1);
        assert_eq!(ddr.stats.bursts, 3);
        assert_eq!(ddr.stats.bytes, 300);
    }

    #[test]
    fn contention_factor_slows_service() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        ddr.contention_factor = 2.0;
        ddr.submit(&mut eng, DdrDir::Read, 1000, Requester::Mm2s);
        let done = drive(&mut ddr, &mut eng);
        assert_eq!(done[0].0, SimTime(2200));
    }

    #[test]
    fn fifo_within_one_requester() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        let a = ddr.submit(&mut eng, DdrDir::Read, 8, Requester::Mm2s);
        let b = ddr.submit(&mut eng, DdrDir::Read, 8, Requester::Mm2s);
        let done = drive(&mut ddr, &mut eng);
        assert_eq!(done[0].1.id, a);
        assert_eq!(done[1].1.id, b);
    }

    #[test]
    #[should_panic(expected = "zero-byte")]
    fn zero_byte_burst_rejected() {
        let mut eng = Engine::new();
        let mut ddr = DdrController::new(&cfg());
        ddr.submit(&mut eng, DdrDir::Read, 0, Requester::Mm2s);
    }
}
