//! Per-tenant service-level accounting for serve runs.
//!
//! Latency is end-to-end: sensor timestamp of the (possibly coalesced)
//! frame → FC-head result delivered. Histograms are log-bucketed
//! ([`crate::util::stats::LogHistogram`]) so tails are cheap to keep and
//! cheap to merge across tenants (aggregate p99/p99.9) or sweep cells.
//! A tenant with zero completions renders as a dropped row (`None`
//! percentiles), never a crash — the `util::stats` empty-sample contract.

use crate::sim::time::{Dur, SimTime};
use crate::system::CpuLedger;
use crate::util::json::Json;
use crate::util::stats::LogHistogram;

/// One tenant's lifetime counters over a serve run.
#[derive(Clone, Debug, Default)]
pub struct TenantSlo {
    /// Frames that reached admission.
    pub offered: u64,
    /// Frames that entered a queue as a new entry.
    pub admitted: u64,
    /// Frames shed (tail-drop rejections + drop-oldest evictions).
    pub dropped: u64,
    /// Frames folded into a queued entry (coalesce).
    pub coalesced: u64,
    /// Frames whose result was delivered.
    pub completed: u64,
    /// Frames still queued when the serving horizon closed (admitted,
    /// never dispatched — a shutdown abandons its backlog).
    pub unserved: u64,
    /// Of `completed`, frames delivered past their deadline.
    pub missed: u64,
    /// Frames lost to a board failure (always 0 on a single-board
    /// report; the cluster layer sets it on fleet-wide aggregates so the
    /// ledger identity closes: `offered == completed + dropped +
    /// coalesced + unserved + failed_over`).
    pub failed_over: u64,
    /// End-to-end latency of completed frames, ns.
    pub latency: LogHistogram,
    /// Queueing delay component (admission → service start), ns.
    pub queueing: LogHistogram,
    /// CPU time the OS scheduler granted this tenant's collection +
    /// normalization task.
    pub normalize_cpu: Dur,
    /// High-water mark of the tenant's admission queue.
    pub max_queue: usize,
}

impl TenantSlo {
    /// Record one completion.
    pub fn complete(&mut self, arrived: SimTime, started: SimTime, done: SimTime, deadline: SimTime) {
        self.completed += 1;
        if done > deadline {
            self.missed += 1;
        }
        self.latency.record(done.since(arrived).ns());
        self.queueing.record(started.since(arrived).ns());
    }

    /// Delivered frames per second of serve-run wall time.
    pub fn goodput_fps(&self, duration: Dur) -> f64 {
        if duration == Dur::ZERO {
            return 0.0;
        }
        self.completed as f64 / duration.as_secs()
    }

    /// Fraction of *offered* frames delivered within deadline. Sheds and
    /// misses both count against attainment — the tenant's user saw
    /// neither frame.
    pub fn slo_attainment(&self) -> f64 {
        if self.offered == 0 {
            return 1.0;
        }
        (self.completed - self.missed) as f64 / self.offered as f64
    }

    pub fn drop_rate(&self) -> f64 {
        if self.offered == 0 {
            return 0.0;
        }
        (self.dropped + self.coalesced) as f64 / self.offered as f64
    }

    pub(crate) fn to_json(&self, duration: Dur) -> Json {
        let pct = |h: &LogHistogram, p: f64| Json::num(h.percentile(p).unwrap_or(0.0));
        Json::obj(vec![
            ("offered", Json::num(self.offered as f64)),
            ("admitted", Json::num(self.admitted as f64)),
            ("dropped", Json::num(self.dropped as f64)),
            ("coalesced", Json::num(self.coalesced as f64)),
            ("completed", Json::num(self.completed as f64)),
            ("unserved", Json::num(self.unserved as f64)),
            ("failed_over", Json::num(self.failed_over as f64)),
            ("missed", Json::num(self.missed as f64)),
            ("goodput_fps", Json::num(self.goodput_fps(duration))),
            ("slo_attainment", Json::num(self.slo_attainment())),
            ("latency_mean_ns", Json::num(self.latency.mean())),
            ("latency_p50_ns", pct(&self.latency, 50.0)),
            ("latency_p99_ns", pct(&self.latency, 99.0)),
            ("latency_p999_ns", pct(&self.latency, 99.9)),
            ("latency_max_ns", Json::num(self.latency.max() as f64)),
            ("queueing_p99_ns", pct(&self.queueing, 99.0)),
            ("normalize_cpu_ns", Json::num(self.normalize_cpu.ns() as f64)),
            ("max_queue", Json::num(self.max_queue as f64)),
        ])
    }
}

/// The full outcome of one serve run.
#[derive(Clone, Debug)]
pub struct ServeReport {
    /// Self-description (labels, not config dumps — the config is the
    /// run's provenance).
    pub driver: &'static str,
    pub policy: &'static str,
    pub shed: &'static str,
    pub arrival: &'static str,
    /// Memory-path mode label ("copy" / "zero-hp" / "zero-acp").
    pub memory: &'static str,
    pub engines: usize,
    /// First arrival generated → last frame drained.
    pub duration: Dur,
    pub tenants: Vec<TenantSlo>,
    /// CPU ledger delta over the run.
    pub ledger: CpuLedger,
    /// Simulator events dispatched (the bench harness's throughput
    /// denominator).
    pub events: u64,
}

impl ServeReport {
    pub fn total_offered(&self) -> u64 {
        self.tenants.iter().map(|t| t.offered).sum()
    }

    pub fn total_completed(&self) -> u64 {
        self.tenants.iter().map(|t| t.completed).sum()
    }

    pub fn total_shed(&self) -> u64 {
        self.tenants.iter().map(|t| t.dropped + t.coalesced).sum()
    }

    pub fn total_unserved(&self) -> u64 {
        self.tenants.iter().map(|t| t.unserved).sum()
    }

    pub fn total_missed(&self) -> u64 {
        self.tenants.iter().map(|t| t.missed).sum()
    }

    /// Aggregate delivered frames/sec.
    pub fn goodput_fps(&self) -> f64 {
        if self.duration == Dur::ZERO {
            return 0.0;
        }
        self.total_completed() as f64 / self.duration.as_secs()
    }

    /// Aggregate offered frames/sec.
    pub fn offered_fps(&self) -> f64 {
        if self.duration == Dur::ZERO {
            return 0.0;
        }
        self.total_offered() as f64 / self.duration.as_secs()
    }

    /// Merged latency histogram across tenants (aggregate tail).
    pub fn merged_latency(&self) -> LogHistogram {
        let mut h = LogHistogram::new();
        for t in &self.tenants {
            h.merge(&t.latency);
        }
        h
    }

    /// Merged admission-queue wait histogram across tenants.
    pub fn merged_queueing(&self) -> LogHistogram {
        let mut h = LogHistogram::new();
        for t in &self.tenants {
            h.merge(&t.queueing);
        }
        h
    }

    /// Aggregate SLO attainment over offered frames.
    pub fn slo_attainment(&self) -> f64 {
        let offered = self.total_offered();
        if offered == 0 {
            return 1.0;
        }
        (self.total_completed() - self.total_missed()) as f64 / offered as f64
    }

    /// Max/min per-tenant goodput ratio — the isolation metric the DRR
    /// acceptance gate checks. Tenants that offered nothing are ignored;
    /// a served-nothing tenant makes the ratio infinite.
    pub fn fairness_ratio(&self) -> f64 {
        let mut min = f64::INFINITY;
        let mut max: f64 = 0.0;
        for t in &self.tenants {
            if t.offered == 0 {
                continue;
            }
            let g = t.completed as f64;
            min = min.min(g);
            max = max.max(g);
        }
        if !min.is_finite() || max == 0.0 {
            return 0.0;
        }
        if min == 0.0 {
            return f64::INFINITY;
        }
        max / min
    }

    /// Machine-readable twin (determinism tests compare this string;
    /// `serve --csv` and the sweep reports derive from the same numbers).
    pub fn to_json(&self) -> Json {
        let merged = self.merged_latency();
        let queueing = self.merged_queueing();
        Json::obj(vec![
            ("schema", Json::num(1.0)),
            ("driver", Json::str(self.driver)),
            ("policy", Json::str(self.policy)),
            ("shed", Json::str(self.shed)),
            ("arrival", Json::str(self.arrival)),
            ("memory", Json::str(self.memory)),
            ("engines", Json::num(self.engines as f64)),
            ("duration_ms", Json::num(self.duration.as_ms())),
            ("events", Json::num(self.events as f64)),
            ("offered", Json::num(self.total_offered() as f64)),
            ("completed", Json::num(self.total_completed() as f64)),
            ("shed_frames", Json::num(self.total_shed() as f64)),
            ("unserved", Json::num(self.total_unserved() as f64)),
            ("missed", Json::num(self.total_missed() as f64)),
            ("goodput_fps", Json::num(self.goodput_fps())),
            ("slo_attainment", Json::num(self.slo_attainment())),
            ("fairness_ratio", Json::num(self.fairness_ratio())),
            ("latency_p50_ns", Json::num(merged.percentile(50.0).unwrap_or(0.0))),
            ("latency_p99_ns", Json::num(merged.percentile(99.0).unwrap_or(0.0))),
            ("latency_p999_ns", Json::num(merged.percentile(99.9).unwrap_or(0.0))),
            ("queueing_p50_ns", Json::num(queueing.percentile(50.0).unwrap_or(0.0))),
            ("queueing_p99_ns", Json::num(queueing.percentile(99.0).unwrap_or(0.0))),
            ("queueing_p999_ns", Json::num(queueing.percentile(99.9).unwrap_or(0.0))),
            ("cpu_busy_ms", Json::num(self.ledger.busy.as_ms())),
            ("cpu_freed_ms", Json::num(self.ledger.freed.as_ms())),
            ("cpu_used_by_tasks_ms", Json::num(self.ledger.used_by_tasks.as_ms())),
            (
                "tenants",
                Json::Arr(self.tenants.iter().map(|t| t.to_json(self.duration)).collect()),
            ),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn slo_with(completed: u64, offered: u64) -> TenantSlo {
        let mut t = TenantSlo::default();
        t.offered = offered;
        t.admitted = completed;
        for i in 0..completed {
            t.complete(
                SimTime(i * 1000),
                SimTime(i * 1000 + 100),
                SimTime(i * 1000 + 500),
                SimTime(i * 1000 + 10_000),
            );
        }
        t
    }

    #[test]
    fn tenant_accounting_and_attainment() {
        let mut t = slo_with(10, 12);
        t.dropped = 2;
        assert_eq!(t.completed, 10);
        assert_eq!(t.missed, 0);
        assert!((t.slo_attainment() - 10.0 / 12.0).abs() < 1e-12);
        assert!((t.drop_rate() - 2.0 / 12.0).abs() < 1e-12);
        assert!(t.goodput_fps(Dur::from_secs(2.0)) == 5.0);
        // A late completion counts as missed.
        t.complete(SimTime(0), SimTime(1), SimTime(100), SimTime(50));
        assert_eq!(t.missed, 1);
    }

    #[test]
    fn zero_completion_tenant_is_safe() {
        let t = TenantSlo::default();
        assert_eq!(t.slo_attainment(), 1.0);
        assert_eq!(t.goodput_fps(Dur::from_secs(1.0)), 0.0);
        assert!(t.latency.percentile(99.0).is_none());
    }

    fn report(tenants: Vec<TenantSlo>) -> ServeReport {
        ServeReport {
            driver: "kernel-level drv",
            policy: "drr",
            shed: "tail-drop",
            arrival: "poisson",
            memory: "copy",
            engines: 2,
            duration: Dur::from_secs(1.0),
            tenants,
            ledger: CpuLedger::default(),
            events: 1234,
        }
    }

    #[test]
    fn fairness_ratio_edges() {
        // Balanced service → ratio near 1.
        let r = report(vec![slo_with(10, 10), slo_with(10, 10)]);
        assert!((r.fairness_ratio() - 1.0).abs() < 1e-12);
        // Starved tenant → infinite ratio.
        let r = report(vec![slo_with(10, 10), slo_with(0, 10)]);
        assert!(r.fairness_ratio().is_infinite());
        // Tenant that offered nothing is ignored.
        let r = report(vec![slo_with(10, 10), slo_with(0, 0), slo_with(5, 5)]);
        assert!((r.fairness_ratio() - 2.0).abs() < 1e-12);
        // Nothing served at all.
        let r = report(vec![slo_with(0, 10)]);
        assert_eq!(r.fairness_ratio(), 0.0);
    }

    #[test]
    fn report_json_carries_totals() {
        let r = report(vec![slo_with(8, 10), slo_with(4, 4)]);
        let j = r.to_json();
        assert_eq!(j.get("offered").as_u64(), Some(14));
        assert_eq!(j.get("completed").as_u64(), Some(12));
        assert_eq!(j.get("engines").as_u64(), Some(2));
        assert_eq!(j.get("tenants").as_arr().unwrap().len(), 2);
        assert_eq!(j.get("policy").as_str(), Some("drr"));
        // Round-trips through the parser (the determinism tests diff the
        // serialised form).
        let text = j.to_string_pretty();
        assert!(Json::parse(&text).is_ok());
    }

    #[test]
    fn report_json_carries_queueing_percentiles() {
        // slo_with queues every frame for exactly 100 ns (arrived at
        // i*1000, started at i*1000 + 100), so every percentile of the
        // merged queueing histogram brackets 100 ns.
        let r = report(vec![slo_with(8, 10), slo_with(4, 4)]);
        let q = r.merged_queueing();
        assert_eq!(q.count(), 12);
        let j = r.to_json();
        for key in ["queueing_p50_ns", "queueing_p99_ns", "queueing_p999_ns"] {
            let v = j.get(key).as_f64().expect(key);
            assert!(v > 0.0 && v < 1000.0, "{key} = {v}");
        }
        // No completions → the keys render as 0, not a crash.
        let j = report(vec![TenantSlo::default()]).to_json();
        assert_eq!(j.get("queueing_p99_ns").as_f64(), Some(0.0));
    }
}
