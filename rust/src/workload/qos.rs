//! QoS scheduling policies over the engine pool.
//!
//! A policy answers one question, every time a DMA engine frees up:
//! *which tenant's head frame runs next?* All four policies are
//! **work-conserving** — whenever any queue is backlogged, [`QosState::pick`]
//! returns a tenant — and all are pure functions of (policy state,
//! queue heads, now), so serve runs stay deterministic.
//!
//! * **Fifo** — global arrival order across all queues: no isolation, a
//!   heavy tenant buys throughput share with offered load;
//! * **Drr** — weighted deficit round-robin: each visit credits a tenant
//!   `quantum × weight` frames of service; backlogged tenants are served
//!   in cursor order while their deficit lasts. Service share converges
//!   to the weight ratio regardless of offered load — the classic
//!   isolation result (Shreedhar & Varghese);
//! * **Priority** — strict priority with aging: lower level wins, but a
//!   head frame gains one level per `aging_ns` of queueing delay, so a
//!   backlogged low-priority tenant cannot starve;
//! * **Edf** — earliest deadline first over the queue heads: optimal for
//!   deadline attainment under feasible load, collapses indiscriminately
//!   past saturation.

use crate::sim::time::SimTime;

use super::admission::Admission;
use super::WorkloadConfig;

/// Policy selector (JSON: `workload.policy`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum QosPolicyKind {
    Fifo,
    Drr,
    Priority,
    Edf,
}

impl QosPolicyKind {
    pub fn parse(s: &str) -> Option<QosPolicyKind> {
        match s {
            "fifo" => Some(QosPolicyKind::Fifo),
            "drr" => Some(QosPolicyKind::Drr),
            "priority" => Some(QosPolicyKind::Priority),
            "edf" => Some(QosPolicyKind::Edf),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            QosPolicyKind::Fifo => "fifo",
            QosPolicyKind::Drr => "drr",
            QosPolicyKind::Priority => "priority",
            QosPolicyKind::Edf => "edf",
        }
    }

    /// Every policy, for sweep grids.
    pub const ALL: [QosPolicyKind; 4] = [
        QosPolicyKind::Fifo,
        QosPolicyKind::Drr,
        QosPolicyKind::Priority,
        QosPolicyKind::Edf,
    ];
}

/// Mutable policy state (only DRR carries any between picks).
pub struct QosState {
    kind: QosPolicyKind,
    quantum: u64,
    weights: Vec<u64>,
    priorities: Vec<u64>,
    aging_ns: u64,
    /// DRR: per-tenant deficit, in frames of service credit.
    deficits: Vec<u64>,
    /// DRR: round-robin cursor.
    cursor: usize,
    /// DRR: whether the tenant under the cursor has already received its
    /// per-visit credit (cleared whenever the cursor advances).
    credited: bool,
}

impl QosState {
    pub fn new(wl: &WorkloadConfig) -> QosState {
        let n = wl.tenants as usize;
        QosState {
            kind: wl.policy,
            quantum: wl.drr_quantum,
            weights: (0..n).map(|i| wl.weight(i)).collect(),
            priorities: (0..n).map(|i| wl.priority(i)).collect(),
            aging_ns: wl.aging_ns,
            deficits: vec![0; n],
            cursor: 0,
            credited: false,
        }
    }

    pub fn kind(&self) -> QosPolicyKind {
        self.kind
    }

    /// Choose the tenant whose head frame is served next, or `None` when
    /// every queue is empty. Work conservation: backlog ⇒ `Some`.
    pub fn pick(&mut self, adm: &Admission, now: SimTime) -> Option<usize> {
        if !adm.any_backlog() {
            return None;
        }
        match self.kind {
            QosPolicyKind::Fifo => self.pick_min_by(adm, |f| (f.arrived.ns(), 0u64)),
            QosPolicyKind::Edf => self.pick_min_by(adm, |f| (f.deadline.ns(), f.arrived.ns())),
            QosPolicyKind::Priority => {
                let aging = self.aging_ns;
                let prios = std::mem::take(&mut self.priorities);
                let picked = self.pick_min_by(adm, |f| {
                    // Clamped to 2^31 levels either way so the shifted
                    // sort key below can never wrap. `aging_ns = 0`
                    // disables aging entirely (strict priority).
                    let waited_levels = if aging == 0 {
                        0i64
                    } else {
                        ((now.since(f.arrived).ns() / aging).min(1 << 31)) as i64
                    };
                    let base = prios[f.tenant].min(1 << 31) as i64;
                    let eff = base - waited_levels;
                    // Sort key is unsigned: shift the aged level into
                    // positive territory.
                    ((eff + (1i64 << 32)) as u64, f.arrived.ns())
                });
                self.priorities = prios;
                picked
            }
            QosPolicyKind::Drr => self.pick_drr(adm),
        }
    }

    /// Smallest `(key, arrived)` over the backlogged heads; ties break by
    /// tenant index (stable, deterministic).
    fn pick_min_by(
        &self,
        adm: &Admission,
        key: impl Fn(&super::admission::QueuedFrame) -> (u64, u64),
    ) -> Option<usize> {
        let mut best: Option<((u64, u64), usize)> = None;
        for t in 0..adm.num_tenants() {
            if let Some(head) = adm.head(t) {
                let k = key(head);
                let better = match best {
                    None => true,
                    Some((bk, _)) => k < bk,
                };
                if better {
                    best = Some((k, t));
                }
            }
        }
        best.map(|(_, t)| t)
    }

    fn pick_drr(&mut self, adm: &Admission) -> Option<usize> {
        let n = adm.num_tenants();
        // Two full rotations always suffice: visiting any backlogged
        // tenant credits it `quantum × weight ≥ 1` on arrival, enough to
        // serve one frame. The credit lands on the tenant *under* the
        // cursor before its deficit is tested — crediting only after
        // advancing would skip tenant 0 on the first rotation of a fresh
        // state (cold-start bias).
        for _ in 0..(2 * n) {
            let t = self.cursor;
            if adm.backlogged(t) {
                if !self.credited {
                    self.deficits[t] =
                        self.deficits[t].saturating_add(self.quantum * self.weights[t]);
                    self.credited = true;
                }
                if self.deficits[t] >= 1 {
                    self.deficits[t] -= 1;
                    return Some(t);
                }
            } else {
                // An idle tenant must not bank credit (classic DRR reset
                // — otherwise a returning tenant bursts unfairly).
                self.deficits[t] = 0;
            }
            self.cursor = (self.cursor + 1) % n;
            self.credited = false;
        }
        // Work-conservation backstop (unreachable when the config is
        // validated: quantum and weights are all ≥ 1).
        (0..n).find(|&t| adm.backlogged(t))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::admission::ShedPolicy;
    use crate::workload::generator::FrameArrival;

    fn setup(
        tenants: u64,
        policy: QosPolicyKind,
        weights: Vec<u64>,
        priorities: Vec<u64>,
    ) -> (Admission, QosState) {
        let mut wl = WorkloadConfig::default();
        wl.tenants = tenants;
        wl.policy = policy;
        wl.weights = weights;
        wl.priorities = priorities;
        wl.queue_cap = 64;
        wl.shed = ShedPolicy::TailDrop;
        wl.aging_ns = 1_000_000;
        (Admission::new(&wl), QosState::new(&wl))
    }

    fn offer(adm: &mut Admission, tenant: usize, seq: u64, at: u64, deadline: u64) {
        adm.offer(FrameArrival {
            at: SimTime(at),
            tenant,
            seq,
            deadline: SimTime(deadline),
        });
    }

    #[test]
    fn empty_backlog_picks_none() {
        let (adm, mut qos) = setup(3, QosPolicyKind::Fifo, vec![1], vec![0]);
        assert_eq!(qos.pick(&adm, SimTime(0)), None);
    }

    #[test]
    fn fifo_serves_global_arrival_order() {
        let (mut adm, mut qos) = setup(3, QosPolicyKind::Fifo, vec![1], vec![0]);
        offer(&mut adm, 2, 0, 10, 1000);
        offer(&mut adm, 0, 0, 20, 1000);
        offer(&mut adm, 1, 0, 5, 1000);
        let mut order = Vec::new();
        while let Some(t) = qos.pick(&adm, SimTime(100)) {
            order.push(t);
            adm.pop(t);
        }
        assert_eq!(order, vec![1, 2, 0]);
    }

    #[test]
    fn edf_serves_earliest_deadline() {
        let (mut adm, mut qos) = setup(2, QosPolicyKind::Edf, vec![1], vec![0]);
        offer(&mut adm, 0, 0, 10, 5000);
        offer(&mut adm, 1, 0, 20, 300);
        assert_eq!(qos.pick(&adm, SimTime(50)), Some(1), "tighter deadline first");
    }

    #[test]
    fn drr_share_follows_weights_under_full_backlog() {
        let (mut adm, mut qos) = setup(2, QosPolicyKind::Drr, vec![3, 1], vec![0]);
        for seq in 0..60 {
            offer(&mut adm, 0, seq, seq, 10_000);
            offer(&mut adm, 1, seq, seq, 10_000);
        }
        let mut served = [0usize; 2];
        for _ in 0..40 {
            let t = qos.pick(&adm, SimTime(1000)).unwrap();
            served[t] += 1;
            adm.pop(t);
        }
        // 3:1 weights → 30:10 service (allow rounding slack of one round).
        assert!(
            served[0] >= 27 && served[1] >= 8,
            "weighted share violated: {served:?}"
        );
    }

    #[test]
    fn drr_is_work_conserving_with_single_backlogged_tenant() {
        let (mut adm, mut qos) = setup(4, QosPolicyKind::Drr, vec![1], vec![0]);
        for seq in 0..10 {
            offer(&mut adm, 2, seq, seq, 10_000);
        }
        for _ in 0..10 {
            assert_eq!(qos.pick(&adm, SimTime(0)), Some(2));
            adm.pop(2);
        }
        assert_eq!(qos.pick(&adm, SimTime(0)), None);
    }

    #[test]
    fn drr_does_not_bank_credit_while_idle() {
        let (mut adm, mut qos) = setup(2, QosPolicyKind::Drr, vec![1, 1], vec![0]);
        // Tenant 1 alone for a long stretch.
        for seq in 0..20 {
            offer(&mut adm, 1, seq, seq, 10_000);
        }
        for _ in 0..20 {
            assert_eq!(qos.pick(&adm, SimTime(0)), Some(1));
            adm.pop(1);
        }
        // Tenant 0 shows up: equal weights, so service alternates rather
        // than tenant 0 bursting through banked deficit.
        for seq in 0..10 {
            offer(&mut adm, 0, seq, 100 + seq, 10_000);
            offer(&mut adm, 1, 20 + seq, 100 + seq, 10_000);
        }
        let mut served = [0usize; 2];
        for _ in 0..10 {
            let t = qos.pick(&adm, SimTime(200)).unwrap();
            served[t] += 1;
            adm.pop(t);
        }
        assert!(served[0] >= 4 && served[1] >= 4, "alternation lost: {served:?}");
    }

    #[test]
    fn drr_first_pick_is_tenant_zero_on_fresh_state() {
        // Cold-start regression: a fresh QosState must serve the lowest
        // backlogged tenant first. The pre-fix code credited the tenant
        // *after* advancing the cursor, so tenant 0's deficit was still 0
        // when first tested and tenant 1 won the opening pick.
        let (mut adm, mut qos) = setup(3, QosPolicyKind::Drr, vec![1, 1, 1], vec![0]);
        for t in 0..3 {
            offer(&mut adm, t, 0, 10, 10_000);
        }
        let mut order = Vec::new();
        for _ in 0..3 {
            let t = qos.pick(&adm, SimTime(50)).unwrap();
            order.push(t);
            adm.pop(t);
        }
        assert_eq!(order, vec![0, 1, 2], "cold-start rotation must begin at tenant 0");
    }

    #[test]
    fn priority_with_zero_aging_is_strict_and_does_not_divide_by_zero() {
        // aging_ns = 0 means "aging disabled": strict priority forever.
        let mut wl = WorkloadConfig::default();
        wl.tenants = 2;
        wl.policy = QosPolicyKind::Priority;
        wl.priorities = vec![0, 5];
        wl.queue_cap = 64;
        wl.shed = ShedPolicy::TailDrop;
        wl.aging_ns = 0;
        let mut adm = Admission::new(&wl);
        let mut qos = QosState::new(&wl);
        // Tenant 1 has waited ~forever; with aging disabled the level-0
        // tenant still wins (and the pick must not panic on `/ 0`).
        offer(&mut adm, 0, 0, 1_000_000_000, 10_000_000_000);
        offer(&mut adm, 1, 0, 0, 10_000_000_000);
        assert_eq!(qos.pick(&adm, SimTime(2_000_000_000)), Some(0));
    }

    #[test]
    fn priority_prefers_low_level_but_ages() {
        let (mut adm, mut qos) = setup(2, QosPolicyKind::Priority, vec![1], vec![0, 5]);
        offer(&mut adm, 0, 0, 100, 100_000);
        offer(&mut adm, 1, 0, 0, 100_000);
        // Fresh: the level-0 tenant wins even though tenant 1 arrived first.
        assert_eq!(qos.pick(&adm, SimTime(200)), Some(0));
        adm.pop(0);
        // A *fresh* high-priority frame arrives while tenant 1's head has
        // aged >5 periods (5 × 1 ms): the aged level dips below the fresh
        // level-0 frame and tenant 1 finally runs.
        offer(&mut adm, 0, 1, 5_999_800, 100_000_000);
        assert_eq!(qos.pick(&adm, SimTime(6_000_000)), Some(1));
    }
}
