//! Seeded tenant stream generators.
//!
//! Every tenant draws from its **own** PCG32 stream, selected from
//! `(WorkloadConfig::seed, tenant index)`, and every draw happens in a
//! fixed per-tenant order independent of how the serve loop interleaves
//! service. Open-loop arrivals are therefore a pure function of the
//! config — the determinism guarantee DESIGN.md §11 states: same seed +
//! config → the same arrival timeline, bit for bit, on every rerun and
//! under any sweep worker count.
//!
//! Arrival processes:
//!
//! * **Poisson** — exponential inter-arrival times at the tenant's rate;
//! * **Bursty** — a 2-phase MMPP: the rate alternates between
//!   `hi = 2b/(b+1) · r` and `lo = 2/(b+1) · r` (mean stays `r`) with
//!   exponentially distributed phase dwell — the clumpy traffic a
//!   motion-triggered DVS sensor actually produces;
//! * **Ramp** — a non-homogeneous Poisson process whose rate climbs
//!   linearly from `r/2` to `3r/2` over the horizon (mean `r`),
//!   generated exactly by inverting the cumulative intensity;
//! * **Closed** — closed-loop: each tenant keeps one frame outstanding
//!   and thinks for `Exp(think_ns)` after every completion, the classic
//!   self-paced sensor pipeline.

use std::collections::BinaryHeap;

use crate::sim::rng::Pcg32;
use crate::sim::time::SimTime;

use super::WorkloadConfig;

/// Arrival-process selector (JSON: `workload.arrival`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ArrivalKind {
    Poisson,
    Bursty,
    Ramp,
    Closed,
}

impl ArrivalKind {
    pub fn parse(s: &str) -> Option<ArrivalKind> {
        match s {
            "poisson" => Some(ArrivalKind::Poisson),
            "bursty" => Some(ArrivalKind::Bursty),
            "ramp" => Some(ArrivalKind::Ramp),
            "closed" => Some(ArrivalKind::Closed),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ArrivalKind::Poisson => "poisson",
            ArrivalKind::Bursty => "bursty",
            ArrivalKind::Ramp => "ramp",
            ArrivalKind::Closed => "closed",
        }
    }
}

/// One frame hitting the serving front door.
#[derive(Clone, Copy, PartialEq, Eq, PartialOrd, Ord, Debug)]
pub struct FrameArrival {
    /// Sensor timestamp (latency is measured from here). Field order
    /// matters: the derived `Ord` keys on `(at, tenant, seq)`, which is
    /// the deterministic tie-break the arrival queue relies on.
    pub at: SimTime,
    pub tenant: usize,
    pub seq: u64,
    pub deadline: SimTime,
}

/// Time-ordered arrival source feeding the serve loop. Open-loop streams
/// are fully materialised up front; closed-loop tenants push their next
/// frame on completion.
#[derive(Default)]
pub struct ArrivalQueue {
    heap: BinaryHeap<std::cmp::Reverse<FrameArrival>>,
}

impl ArrivalQueue {
    pub fn new() -> ArrivalQueue {
        ArrivalQueue::default()
    }

    pub fn push(&mut self, a: FrameArrival) {
        self.heap.push(std::cmp::Reverse(a));
    }

    /// Earliest pending arrival instant.
    pub fn peek_at(&self) -> Option<SimTime> {
        self.heap.peek().map(|r| r.0.at)
    }

    /// Pop the earliest arrival if it has happened by `now`.
    pub fn pop_due(&mut self, now: SimTime) -> Option<FrameArrival> {
        if self.heap.peek().is_some_and(|r| r.0.at <= now) {
            self.heap.pop().map(|r| r.0)
        } else {
            None
        }
    }

    pub fn is_empty(&self) -> bool {
        self.heap.is_empty()
    }

    pub fn len(&self) -> usize {
        self.heap.len()
    }
}

/// Per-tenant stream generator set.
pub struct StreamGenerator {
    kind: ArrivalKind,
    duration_ns: u64,
    deadline_ns: u64,
    think_ns: u64,
    burst_factor: f64,
    burst_dwell_ns: u64,
    rates: Vec<f64>,
    rngs: Vec<Pcg32>,
    seqs: Vec<u64>,
}

impl StreamGenerator {
    pub fn new(wl: &WorkloadConfig) -> StreamGenerator {
        let n = wl.tenants as usize;
        StreamGenerator {
            kind: wl.arrival,
            duration_ns: wl.duration_ns,
            deadline_ns: wl.deadline_ns,
            think_ns: wl.think_ns,
            burst_factor: wl.burst_factor,
            burst_dwell_ns: wl.burst_dwell_ns,
            rates: (0..n).map(|i| wl.tenant_fps(i)).collect(),
            // One independent PCG32 stream per tenant: stream selection
            // keeps tenants uncorrelated even under the same seed.
            rngs: (0..n)
                .map(|i| Pcg32::with_stream(wl.seed, 0x7E4A_7000 + i as u64))
                .collect(),
            seqs: vec![0; n],
        }
    }

    pub fn tenants(&self) -> usize {
        self.rates.len()
    }

    pub fn tenant_rate(&self, t: usize) -> f64 {
        self.rates[t]
    }

    fn frame(&mut self, tenant: usize, at_ns: u64) -> FrameArrival {
        let seq = self.seqs[tenant];
        self.seqs[tenant] += 1;
        FrameArrival {
            at: SimTime(at_ns),
            tenant,
            seq,
            deadline: SimTime(at_ns + self.deadline_ns),
        }
    }

    /// Materialise the initial arrival set into `q`: the whole horizon
    /// for open-loop kinds, the first frame per tenant for closed-loop.
    /// Returns the number of arrivals pushed.
    pub fn initial(&mut self, q: &mut ArrivalQueue) -> usize {
        let mut pushed = 0;
        for t in 0..self.tenants() {
            match self.kind {
                ArrivalKind::Poisson => pushed += self.gen_poisson(t, q),
                ArrivalKind::Bursty => pushed += self.gen_bursty(t, q),
                ArrivalKind::Ramp => pushed += self.gen_ramp(t, q),
                ArrivalKind::Closed => {
                    let think = self.rngs[t].next_exp(self.think_ns as f64).max(1.0) as u64;
                    if think < self.duration_ns {
                        let f = self.frame(t, think);
                        q.push(f);
                        pushed += 1;
                    }
                }
            }
        }
        pushed
    }

    /// Closed-loop pacing: called by the serve loop when tenant `t`'s
    /// frame completes at `now`. Open-loop streams return `None` (their
    /// arrivals were materialised up front).
    pub fn on_complete(&mut self, t: usize, now: SimTime) -> Option<FrameArrival> {
        if self.kind != ArrivalKind::Closed {
            return None;
        }
        let think = self.rngs[t].next_exp(self.think_ns as f64).max(1.0) as u64;
        let at = now.ns() + think;
        if at >= self.duration_ns {
            return None;
        }
        Some(self.frame(t, at))
    }

    fn gen_poisson(&mut self, t: usize, q: &mut ArrivalQueue) -> usize {
        let mean_ns = 1e9 / self.rates[t];
        let mut at = 0f64;
        let mut pushed = 0;
        loop {
            at += self.rngs[t].next_exp(mean_ns).max(1.0);
            if at >= self.duration_ns as f64 {
                return pushed;
            }
            let f = self.frame(t, at as u64);
            q.push(f);
            pushed += 1;
        }
    }

    fn gen_bursty(&mut self, t: usize, q: &mut ArrivalQueue) -> usize {
        let r = self.rates[t];
        let b = self.burst_factor;
        let hi = 2.0 * b / (b + 1.0) * r;
        let lo = 2.0 / (b + 1.0) * r;
        let mut in_hi = true;
        let mut at = 0f64;
        let mut phase_end = self.rngs[t].next_exp(self.burst_dwell_ns as f64);
        let mut pushed = 0;
        while at < self.duration_ns as f64 {
            let rate = if in_hi { hi } else { lo };
            let dt = self.rngs[t].next_exp(1e9 / rate).max(1.0);
            if at + dt >= phase_end {
                // The exponential is memoryless: restarting the draw at
                // the phase boundary keeps the process exact.
                at = phase_end;
                in_hi = !in_hi;
                phase_end = at + self.rngs[t].next_exp(self.burst_dwell_ns as f64);
                continue;
            }
            at += dt;
            if at >= self.duration_ns as f64 {
                break;
            }
            let f = self.frame(t, at as u64);
            q.push(f);
            pushed += 1;
        }
        pushed
    }

    fn gen_ramp(&mut self, t: usize, q: &mut ArrivalQueue) -> usize {
        // rate(u) = r·(0.5 + u/D) for u in [0, D] seconds: inversion of
        // the cumulative intensity Λ gives exact event times.
        let r = self.rates[t];
        let dur_s = self.duration_ns as f64 * 1e-9;
        let a = r / (2.0 * dur_s); // d(rate)/du / 2
        let mut at_s = 0f64;
        let mut pushed = 0;
        loop {
            let e = self.rngs[t].next_exp(1.0);
            let b = r * (0.5 + at_s / dur_s);
            // Solve a·Δ² + b·Δ − e = 0 for the next inter-arrival Δ.
            let delta = if a > 0.0 {
                (-b + (b * b + 4.0 * a * e).sqrt()) / (2.0 * a)
            } else {
                e / b
            };
            at_s += delta.max(1e-9);
            if at_s >= dur_s {
                return pushed;
            }
            let f = self.frame(t, (at_s * 1e9) as u64);
            q.push(f);
            pushed += 1;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn wl(kind: ArrivalKind) -> WorkloadConfig {
        let mut w = WorkloadConfig::default();
        w.arrival = kind;
        w.tenants = 3;
        w.offered_fps = 300.0;
        w.duration_ns = 500_000_000;
        w
    }

    fn drain(q: &mut ArrivalQueue) -> Vec<FrameArrival> {
        let mut v = Vec::new();
        while let Some(a) = q.pop_due(SimTime(u64::MAX)) {
            v.push(a);
        }
        v
    }

    #[test]
    fn open_loop_kinds_are_deterministic_and_in_horizon() {
        for kind in [ArrivalKind::Poisson, ArrivalKind::Bursty, ArrivalKind::Ramp] {
            let run = || {
                let w = wl(kind);
                let mut g = StreamGenerator::new(&w);
                let mut q = ArrivalQueue::new();
                g.initial(&mut q);
                drain(&mut q)
            };
            let a = run();
            let b = run();
            assert_eq!(a, b, "{kind:?} not reproducible");
            assert!(!a.is_empty(), "{kind:?} generated nothing");
            for f in &a {
                assert!(f.at.ns() < 500_000_000, "{kind:?} arrival past horizon");
                assert_eq!(f.deadline.ns(), f.at.ns() + wl(kind).deadline_ns);
            }
            // Queue pops in global time order.
            for w2 in a.windows(2) {
                assert!(w2[0].at <= w2[1].at);
            }
        }
    }

    #[test]
    fn poisson_rate_roughly_matches() {
        let mut w = wl(ArrivalKind::Poisson);
        w.tenants = 1;
        w.offered_fps = 2000.0;
        w.duration_ns = 1_000_000_000;
        let mut g = StreamGenerator::new(&w);
        let mut q = ArrivalQueue::new();
        let n = g.initial(&mut q);
        let expect = 2000.0;
        assert!(
            (n as f64 - expect).abs() / expect < 0.10,
            "poisson count {n} vs expected {expect}"
        );
    }

    #[test]
    fn skewed_rates_generate_skewed_counts() {
        let mut w = wl(ArrivalKind::Poisson);
        w.skew = 6.0;
        w.offered_fps = 1000.0;
        w.duration_ns = 1_000_000_000;
        let mut g = StreamGenerator::new(&w);
        let mut q = ArrivalQueue::new();
        g.initial(&mut q);
        let mut per = [0usize; 3];
        for a in drain(&mut q) {
            per[a.tenant] += 1;
        }
        assert!(per[2] > 8 * per[0], "skew not visible: {per:?}");
    }

    #[test]
    fn bursty_is_clumpier_than_poisson() {
        // Coefficient of variation of inter-arrival times: MMPP > 1,
        // Poisson ≈ 1.
        let cv = |kind| {
            let mut w = wl(kind);
            w.tenants = 1;
            w.offered_fps = 1000.0;
            w.duration_ns = 2_000_000_000;
            w.burst_factor = 8.0;
            let mut g = StreamGenerator::new(&w);
            let mut q = ArrivalQueue::new();
            g.initial(&mut q);
            let at: Vec<f64> = drain(&mut q).iter().map(|a| a.at.ns() as f64).collect();
            let gaps: Vec<f64> = at.windows(2).map(|w2| w2[1] - w2[0]).collect();
            let mean = gaps.iter().sum::<f64>() / gaps.len() as f64;
            let var = gaps.iter().map(|g2| (g2 - mean).powi(2)).sum::<f64>()
                / (gaps.len() - 1) as f64;
            var.sqrt() / mean
        };
        let poisson = cv(ArrivalKind::Poisson);
        let bursty = cv(ArrivalKind::Bursty);
        assert!(bursty > poisson * 1.15, "bursty cv {bursty} !> poisson cv {poisson}");
    }

    #[test]
    fn ramp_back_half_denser_than_front_half() {
        let mut w = wl(ArrivalKind::Ramp);
        w.tenants = 1;
        w.offered_fps = 2000.0;
        w.duration_ns = 1_000_000_000;
        let mut g = StreamGenerator::new(&w);
        let mut q = ArrivalQueue::new();
        g.initial(&mut q);
        let half = w.duration_ns / 2;
        let (mut front, mut back) = (0usize, 0usize);
        for a in drain(&mut q) {
            if a.at.ns() < half {
                front += 1;
            } else {
                back += 1;
            }
        }
        // Expected 3:5 split (integral of the ramp) — require a clear gap.
        assert!(back as f64 > front as f64 * 1.3, "front {front} back {back}");
    }

    #[test]
    fn closed_loop_paces_on_completions() {
        let mut w = wl(ArrivalKind::Closed);
        w.tenants = 2;
        let mut g = StreamGenerator::new(&w);
        let mut q = ArrivalQueue::new();
        assert_eq!(g.initial(&mut q), 2, "one seed frame per tenant");
        let first = q.pop_due(SimTime(u64::MAX)).unwrap();
        let next = g.on_complete(first.tenant, SimTime(10_000_000)).unwrap();
        assert!(next.at.ns() > 10_000_000);
        assert_eq!(next.seq, first.seq + 1);
        // Past the horizon no new frame is issued.
        assert!(g.on_complete(first.tenant, SimTime(w.duration_ns)).is_none());
        // Open-loop generators never emit on completion.
        let mut g2 = StreamGenerator::new(&wl(ArrivalKind::Poisson));
        assert!(g2.on_complete(0, SimTime(0)).is_none());
    }

    #[test]
    fn arrival_queue_orders_and_gates_on_time() {
        let mut q = ArrivalQueue::new();
        let f = |at, tenant, seq| FrameArrival {
            at: SimTime(at),
            tenant,
            seq,
            deadline: SimTime(at + 1),
        };
        q.push(f(50, 1, 0));
        q.push(f(10, 0, 0));
        q.push(f(10, 2, 0));
        assert_eq!(q.peek_at(), Some(SimTime(10)));
        assert!(q.pop_due(SimTime(5)).is_none(), "future arrivals stay queued");
        assert_eq!(q.pop_due(SimTime(10)).unwrap().tenant, 0, "ties break by tenant");
        assert_eq!(q.pop_due(SimTime(10)).unwrap().tenant, 2);
        assert!(q.pop_due(SimTime(10)).is_none());
        assert_eq!(q.len(), 1);
        assert_eq!(q.pop_due(SimTime(100)).unwrap().tenant, 1);
        assert!(q.is_empty());
    }
}
