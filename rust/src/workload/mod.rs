//! Multi-tenant serving workload model: stream generators, admission
//! control and QoS scheduling over the multi-engine DMA pool.
//!
//! The paper's closing argument is that the kernel-level driver wins not
//! on raw latency but because it frees the OS "to manage other important
//! processes" — a claim that only has teeth once the accelerator is a
//! *shared service* under concurrent load. This subsystem supplies that
//! load: `N` tenants, each a DAVIS-style frame stream with its own
//! arrival process, rate, deadline and priority, multiplexed onto the
//! engine pool through bounded admission queues and a pluggable QoS
//! policy.
//!
//! * [`generator`] — seeded open-loop (Poisson, bursty/MMPP, linear
//!   ramp) and closed-loop sensor-stream generators. Every arrival is a
//!   pure function of [`WorkloadConfig::seed`], so serve runs are
//!   bit-replayable;
//! * [`admission`] — bounded per-tenant queues with shed policies
//!   (tail-drop, drop-oldest, frame-coalescing — the merge a real
//!   neuromorphic pipeline performs when it falls behind the sensor);
//! * [`qos`] — the scheduling policies over the engine pool: global
//!   FIFO, weighted deficit-round-robin, strict priority with aging,
//!   and earliest-deadline-first;
//! * [`slo`] — per-tenant accounting: log-bucketed latency histograms
//!   ([`crate::util::stats::LogHistogram`]), goodput, drop/coalesce
//!   rates and SLO attainment.
//!
//! The execution loop that wires these onto the simulator lives in
//! [`crate::coordinator::serve`]; the knobs live under the `workload`
//! key of the JSON config (same override mechanism as `faults`). See
//! DESIGN.md §11 for the policy contracts and the determinism guarantee.

pub mod admission;
pub mod generator;
pub mod qos;
pub mod slo;

pub use admission::{Admission, AdmitOutcome, QueuedFrame, ShedPolicy};
pub use generator::{ArrivalKind, ArrivalQueue, FrameArrival, StreamGenerator};
pub use qos::{QosPolicyKind, QosState};
pub use slo::{ServeReport, TenantSlo};

use crate::util::json::Json;

/// All serving-workload knobs, JSON-configurable under the `workload`
/// key of [`crate::config::SimConfig`]. Per-tenant vectors follow the
/// `ddr_engine_weights` convention: tenants beyond the list inherit the
/// last entry, so `[1]` means "all equal".
#[derive(Clone, Debug, PartialEq)]
pub struct WorkloadConfig {
    /// Seed of the generators' PCG32 streams (independent of the main
    /// simulator seed so arrival patterns can be varied in isolation).
    pub seed: u64,
    /// Number of tenant streams.
    pub tenants: u64,
    /// Aggregate offered load across all tenants, frames/second
    /// (open-loop kinds; closed-loop paces itself via `think_ns`).
    pub offered_fps: f64,
    /// Per-tenant rate skew: tenant `i`'s share is proportional to
    /// `skew^i`. `1.0` = uniform; `4.0` with 3 tenants = 1:4:16.
    pub skew: f64,
    /// Arrival process (`"poisson"`, `"bursty"`, `"ramp"`, `"closed"`).
    pub arrival: ArrivalKind,
    /// Bursty (MMPP-2): peak-to-trough rate ratio (mean stays
    /// `offered_fps`).
    pub burst_factor: f64,
    /// Bursty: mean dwell time per phase.
    pub burst_dwell_ns: u64,
    /// Closed-loop: mean think time between a completion and the
    /// tenant's next frame.
    pub think_ns: u64,
    /// Generation horizon; queued frames admitted before it still drain.
    pub duration_ns: u64,
    /// Per-frame deadline, from sensor timestamp to result delivered.
    pub deadline_ns: u64,
    /// Bound of each tenant's admission queue.
    pub queue_cap: u64,
    /// What to shed when a queue is full (`"tail-drop"`,
    /// `"drop-oldest"`, `"coalesce"`).
    pub shed: ShedPolicy,
    /// Engine-pool scheduling policy (`"fifo"`, `"drr"`, `"priority"`,
    /// `"edf"`).
    pub policy: QosPolicyKind,
    /// DRR: frames of credit added per round (scaled by the tenant's
    /// weight).
    pub drr_quantum: u64,
    /// DRR service weights per tenant (inherit-last).
    pub weights: Vec<u64>,
    /// Strict-priority levels per tenant, lower = more urgent
    /// (inherit-last).
    pub priorities: Vec<u64>,
    /// Priority aging: a waiting head frame gains one priority level per
    /// this much queueing delay, so low-priority tenants cannot starve.
    /// 0 disables aging (strict priority, starvation possible).
    pub aging_ns: u64,
    /// CPU demand per admitted frame for the PS-side collection +
    /// normalization task — the "other important processes" of §V,
    /// scheduled onto whatever CPU the driver frees.
    pub normalize_ns: u64,
}

impl Default for WorkloadConfig {
    fn default() -> Self {
        WorkloadConfig {
            seed: 0x5E21_F00D,
            tenants: 4,
            offered_fps: 60.0,
            skew: 1.0,
            arrival: ArrivalKind::Poisson,
            burst_factor: 4.0,
            burst_dwell_ns: 50_000_000,
            think_ns: 5_000_000,
            duration_ns: 1_000_000_000,
            deadline_ns: 50_000_000,
            queue_cap: 8,
            shed: ShedPolicy::TailDrop,
            policy: QosPolicyKind::Drr,
            drr_quantum: 1,
            weights: vec![1],
            priorities: vec![0],
            aging_ns: 20_000_000,
            normalize_ns: 300_000,
        }
    }
}

impl WorkloadConfig {
    /// Tenant `i`'s entry of an inherit-last per-tenant vector.
    fn inherit_last(v: &[u64], i: usize) -> u64 {
        *v.get(i).or_else(|| v.last()).expect("validated non-empty")
    }

    /// DRR weight of tenant `i`.
    pub fn weight(&self, i: usize) -> u64 {
        Self::inherit_last(&self.weights, i)
    }

    /// Priority level of tenant `i` (lower = more urgent).
    pub fn priority(&self, i: usize) -> u64 {
        Self::inherit_last(&self.priorities, i)
    }

    /// Tenant `i`'s offered rate in frames/sec (skew-weighted share of
    /// the aggregate).
    pub fn tenant_fps(&self, i: usize) -> f64 {
        let n = self.tenants as usize;
        let total: f64 = (0..n).map(|j| self.skew.powi(j as i32)).sum();
        self.offered_fps * self.skew.powi(i as i32) / total
    }

    /// Apply overrides from a parsed JSON object; unknown keys are an
    /// error (same contract as the top-level config).
    pub fn apply_json(&mut self, v: &Json) -> anyhow::Result<()> {
        let obj = v
            .as_obj()
            .ok_or_else(|| anyhow::anyhow!("workload config must be a JSON object"))?;
        for (k, val) in obj {
            let need_u64 = || {
                val.as_u64()
                    .ok_or_else(|| anyhow::anyhow!("workload.{k} must be a non-negative integer"))
            };
            let need_f64 =
                || val.as_f64().ok_or_else(|| anyhow::anyhow!("workload.{k} must be a number"));
            let need_str =
                || val.as_str().ok_or_else(|| anyhow::anyhow!("workload.{k} must be a string"));
            match k.as_str() {
                "seed" => self.seed = need_u64()?,
                "tenants" => self.tenants = need_u64()?,
                "offered_fps" => self.offered_fps = need_f64()?,
                "skew" => self.skew = need_f64()?,
                "arrival" => {
                    self.arrival = ArrivalKind::parse(need_str()?).ok_or_else(|| {
                        anyhow::anyhow!(
                            "workload.arrival must be \"poisson\", \"bursty\", \"ramp\" or \
                             \"closed\""
                        )
                    })?
                }
                "burst_factor" => self.burst_factor = need_f64()?,
                "burst_dwell_ns" => self.burst_dwell_ns = need_u64()?,
                "think_ns" => self.think_ns = need_u64()?,
                "duration_ns" => self.duration_ns = need_u64()?,
                "deadline_ns" => self.deadline_ns = need_u64()?,
                "queue_cap" => self.queue_cap = need_u64()?,
                "shed" => {
                    self.shed = ShedPolicy::parse(need_str()?).ok_or_else(|| {
                        anyhow::anyhow!(
                            "workload.shed must be \"tail-drop\", \"drop-oldest\" or \"coalesce\""
                        )
                    })?
                }
                "policy" => {
                    self.policy = QosPolicyKind::parse(need_str()?).ok_or_else(|| {
                        anyhow::anyhow!(
                            "workload.policy must be \"fifo\", \"drr\", \"priority\" or \"edf\""
                        )
                    })?
                }
                "drr_quantum" => self.drr_quantum = need_u64()?,
                "weights" => self.weights = parse_u64_vec(val, k)?,
                "priorities" => self.priorities = parse_u64_vec(val, k)?,
                "aging_ns" => self.aging_ns = need_u64()?,
                "normalize_ns" => self.normalize_ns = need_u64()?,
                _ => anyhow::bail!("unknown workload config key: {k}"),
            }
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("seed", Json::num(self.seed as f64)),
            ("tenants", Json::num(self.tenants as f64)),
            ("offered_fps", Json::num(self.offered_fps)),
            ("skew", Json::num(self.skew)),
            ("arrival", Json::str(self.arrival.label())),
            ("burst_factor", Json::num(self.burst_factor)),
            ("burst_dwell_ns", Json::num(self.burst_dwell_ns as f64)),
            ("think_ns", Json::num(self.think_ns as f64)),
            ("duration_ns", Json::num(self.duration_ns as f64)),
            ("deadline_ns", Json::num(self.deadline_ns as f64)),
            ("queue_cap", Json::num(self.queue_cap as f64)),
            ("shed", Json::str(self.shed.label())),
            ("policy", Json::str(self.policy.label())),
            ("drr_quantum", Json::num(self.drr_quantum as f64)),
            (
                "weights",
                Json::Arr(self.weights.iter().map(|&w| Json::num(w as f64)).collect()),
            ),
            (
                "priorities",
                Json::Arr(self.priorities.iter().map(|&p| Json::num(p as f64)).collect()),
            ),
            ("aging_ns", Json::num(self.aging_ns as f64)),
            ("normalize_ns", Json::num(self.normalize_ns as f64)),
        ])
    }

    pub fn validate(&self) -> anyhow::Result<()> {
        anyhow::ensure!(
            self.tenants >= 1 && self.tenants <= 64,
            "workload.tenants must be in [1, 64]"
        );
        // Upper bounds keep the open-loop generators from materialising
        // absurd arrival sets (offered_fps × duration frames are built
        // up front); finiteness guards NaN/inf from JSON like `1e999`.
        anyhow::ensure!(
            self.offered_fps.is_finite() && self.offered_fps > 0.0
                && self.offered_fps <= 100_000.0,
            "workload.offered_fps must be in (0, 1e5]"
        );
        anyhow::ensure!(
            self.skew.is_finite() && self.skew > 0.0 && self.skew <= 64.0,
            "workload.skew must be in (0, 64]"
        );
        anyhow::ensure!(
            self.burst_factor.is_finite() && (1.0..=1000.0).contains(&self.burst_factor),
            "workload.burst_factor must be in [1, 1000]"
        );
        anyhow::ensure!(
            self.burst_dwell_ns >= 1 && self.burst_dwell_ns <= 60_000_000_000,
            "workload.burst_dwell_ns must be in [1, 60e9]"
        );
        anyhow::ensure!(
            self.think_ns >= 1 && self.think_ns <= 60_000_000_000,
            "workload.think_ns must be in [1, 60e9]"
        );
        anyhow::ensure!(
            self.duration_ns >= 1 && self.duration_ns <= 30_000_000_000,
            "workload.duration_ns must be in [1, 30e9] (a 30 s horizon bounds the \
             materialised arrival set)"
        );
        // Upper bounds on the integer knobs keep u64 arithmetic off the
        // overflow cliff (quantum × weight deficit credit, timestamp +
        // deadline/think sums).
        anyhow::ensure!(
            self.deadline_ns >= 1 && self.deadline_ns <= 1_000_000_000_000,
            "workload.deadline_ns must be in [1, 1e12]"
        );
        anyhow::ensure!(
            self.queue_cap >= 1 && self.queue_cap <= 1_000_000,
            "workload.queue_cap must be in [1, 1e6]"
        );
        anyhow::ensure!(
            self.drr_quantum >= 1 && self.drr_quantum <= 1_000,
            "workload.drr_quantum must be in [1, 1000]"
        );
        anyhow::ensure!(
            !self.weights.is_empty()
                && self.weights.iter().all(|&w| (1..=1_000).contains(&w)),
            "workload.weights must be non-empty with every weight in [1, 1000]"
        );
        anyhow::ensure!(
            !self.priorities.is_empty()
                && self.priorities.iter().all(|&p| p <= 1_000_000),
            "workload.priorities must be non-empty with every level <= 1e6"
        );
        anyhow::ensure!(
            self.aging_ns <= 1_000_000_000_000,
            "workload.aging_ns must be in [0, 1e12] (0 disables aging)"
        );
        anyhow::ensure!(
            self.normalize_ns <= 1_000_000_000,
            "workload.normalize_ns must be <= 1e9"
        );
        Ok(())
    }
}

fn parse_u64_vec(val: &Json, key: &str) -> anyhow::Result<Vec<u64>> {
    val.as_arr()
        .ok_or_else(|| anyhow::anyhow!("workload.{key} must be an array"))?
        .iter()
        .map(|x| {
            x.as_u64().ok_or_else(|| {
                anyhow::anyhow!("workload.{key} must hold non-negative integers")
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_validate() {
        WorkloadConfig::default().validate().unwrap();
    }

    #[test]
    fn json_roundtrip_identity() {
        let mut wl = WorkloadConfig::default();
        wl.tenants = 6;
        wl.arrival = ArrivalKind::Bursty;
        wl.shed = ShedPolicy::Coalesce;
        wl.policy = QosPolicyKind::Edf;
        wl.weights = vec![3, 1];
        wl.priorities = vec![0, 2];
        let json = wl.to_json();
        let mut back = WorkloadConfig::default();
        back.apply_json(&json).unwrap();
        assert_eq!(wl, back);
    }

    #[test]
    fn unknown_and_bad_keys_rejected() {
        let mut wl = WorkloadConfig::default();
        assert!(wl.apply_json(&Json::parse(r#"{"tenant_count": 3}"#).unwrap()).is_err());
        assert!(wl.apply_json(&Json::parse(r#"{"policy": "lottery"}"#).unwrap()).is_err());
        assert!(wl.apply_json(&Json::parse(r#"{"arrival": 7}"#).unwrap()).is_err());
        assert!(wl.apply_json(&Json::parse(r#"{"weights": [1, "x"]}"#).unwrap()).is_err());
        // Valid override applies.
        wl.apply_json(&Json::parse(r#"{"policy": "edf", "queue_cap": 3}"#).unwrap()).unwrap();
        assert_eq!(wl.policy, QosPolicyKind::Edf);
        assert_eq!(wl.queue_cap, 3);
    }

    #[test]
    fn validation_bounds() {
        let mut wl = WorkloadConfig::default();
        wl.tenants = 0;
        assert!(wl.validate().is_err());
        let mut wl = WorkloadConfig::default();
        wl.queue_cap = 0;
        assert!(wl.validate().is_err());
        let mut wl = WorkloadConfig::default();
        wl.weights = vec![0];
        assert!(wl.validate().is_err());
        let mut wl = WorkloadConfig::default();
        wl.burst_factor = 0.5;
        assert!(wl.validate().is_err());
        // OOM guards: absurd rates, infinities and multi-minute horizons
        // are rejected before the generators materialise arrivals.
        let mut wl = WorkloadConfig::default();
        wl.offered_fps = 1e12;
        assert!(wl.validate().is_err());
        let mut wl = WorkloadConfig::default();
        wl.offered_fps = f64::INFINITY;
        assert!(wl.validate().is_err());
        let mut wl = WorkloadConfig::default();
        wl.skew = f64::NAN;
        assert!(wl.validate().is_err());
        let mut wl = WorkloadConfig::default();
        wl.duration_ns = 120_000_000_000;
        assert!(wl.validate().is_err());
    }

    #[test]
    fn tenant_rates_split_the_aggregate() {
        let mut wl = WorkloadConfig::default();
        wl.tenants = 3;
        wl.offered_fps = 70.0;
        wl.skew = 1.0;
        for i in 0..3 {
            assert!((wl.tenant_fps(i) - 70.0 / 3.0).abs() < 1e-9);
        }
        wl.skew = 6.0;
        let total: f64 = (0..3).map(|i| wl.tenant_fps(i)).sum();
        assert!((total - 70.0).abs() < 1e-9);
        assert!(wl.tenant_fps(2) / wl.tenant_fps(0) > 35.0, "skew^2 = 36x spread");
    }

    #[test]
    fn inherit_last_vectors() {
        let mut wl = WorkloadConfig::default();
        wl.tenants = 4;
        wl.weights = vec![4, 2];
        wl.priorities = vec![0, 1, 3];
        assert_eq!(wl.weight(0), 4);
        assert_eq!(wl.weight(3), 2);
        assert_eq!(wl.priority(2), 3);
        assert_eq!(wl.priority(3), 3);
    }
}
