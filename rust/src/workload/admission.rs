//! Bounded per-tenant admission queues with shed policies.
//!
//! Every tenant owns one FIFO of at most `WorkloadConfig::queue_cap`
//! frames. When a frame arrives to a full queue the shed policy decides
//! what gives:
//!
//! * **TailDrop** — the newcomer is rejected (classic router behaviour:
//!   cheapest, but under sustained overload the queue holds the *stalest*
//!   frames);
//! * **DropOldest** — the head is evicted and the newcomer admitted
//!   (bounded staleness: the sensor's freshest data wins);
//! * **Coalesce** — the newcomer *replaces* the newest queued frame,
//!   folding into one entry — exactly what a neuromorphic pipeline does
//!   when it falls behind: accumulate events into the pending histogram
//!   frame instead of growing a backlog. The superseded payload is
//!   accounted as `coalesced`, not dropped.
//!
//! Accounting contract (asserted by `rust/tests/serve_property.rs`):
//! every offered frame ends in exactly one of {admitted-and-served,
//! dropped, coalesced}, and a queue's depth never exceeds its bound.

use std::collections::VecDeque;

use crate::sim::time::SimTime;

use super::generator::FrameArrival;
use super::WorkloadConfig;

/// Shed policy selector (JSON: `workload.shed`).
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum ShedPolicy {
    TailDrop,
    DropOldest,
    Coalesce,
}

impl ShedPolicy {
    pub fn parse(s: &str) -> Option<ShedPolicy> {
        match s {
            "tail-drop" => Some(ShedPolicy::TailDrop),
            "drop-oldest" => Some(ShedPolicy::DropOldest),
            "coalesce" => Some(ShedPolicy::Coalesce),
            _ => None,
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ShedPolicy::TailDrop => "tail-drop",
            ShedPolicy::DropOldest => "drop-oldest",
            ShedPolicy::Coalesce => "coalesce",
        }
    }
}

/// A frame sitting in an admission queue.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub struct QueuedFrame {
    pub tenant: usize,
    pub seq: u64,
    /// Sensor timestamp (latency measured from here).
    pub arrived: SimTime,
    pub deadline: SimTime,
    /// How many earlier frames were folded into this one (Coalesce).
    pub coalesced: u64,
}

/// What happened to an offered frame at the front door.
#[derive(Clone, Copy, PartialEq, Eq, Debug)]
pub enum AdmitOutcome {
    /// Entered the queue as a new entry.
    Admitted,
    /// Rejected outright (TailDrop on a full queue).
    DroppedNew,
    /// Admitted, but the queue's oldest frame was evicted to make room
    /// (DropOldest). The payload is the evicted frame.
    DroppedOldest(QueuedFrame),
    /// Folded into the newest queued entry (Coalesce): the queued
    /// payload was superseded, the entry now carries this frame's data
    /// and deadline.
    Coalesced,
}

/// One tenant's bounded queue plus its lifetime counters.
#[derive(Clone, Debug)]
pub struct TenantQueue {
    cap: usize,
    q: VecDeque<QueuedFrame>,
    /// Frames that reached the front door.
    pub offered: u64,
    /// Frames that entered the queue as a new entry.
    pub admitted: u64,
    /// Frames shed (TailDrop rejections + DropOldest evictions).
    pub dropped: u64,
    /// Frames folded into a queued entry (Coalesce).
    pub coalesced: u64,
    /// High-water mark of the queue depth.
    pub max_depth: usize,
}

impl TenantQueue {
    fn new(cap: usize) -> TenantQueue {
        TenantQueue {
            cap,
            q: VecDeque::with_capacity(cap),
            offered: 0,
            admitted: 0,
            dropped: 0,
            coalesced: 0,
            max_depth: 0,
        }
    }

    pub fn len(&self) -> usize {
        self.q.len()
    }

    pub fn is_empty(&self) -> bool {
        self.q.is_empty()
    }

    pub fn cap(&self) -> usize {
        self.cap
    }

    pub fn head(&self) -> Option<&QueuedFrame> {
        self.q.front()
    }

    fn push(&mut self, f: QueuedFrame) {
        self.q.push_back(f);
        self.admitted += 1;
        self.max_depth = self.max_depth.max(self.q.len());
    }

    fn offer(&mut self, a: FrameArrival, shed: ShedPolicy) -> AdmitOutcome {
        self.offered += 1;
        let f = QueuedFrame {
            tenant: a.tenant,
            seq: a.seq,
            arrived: a.at,
            deadline: a.deadline,
            coalesced: 0,
        };
        if self.q.len() < self.cap {
            self.push(f);
            return AdmitOutcome::Admitted;
        }
        match shed {
            ShedPolicy::TailDrop => {
                self.dropped += 1;
                AdmitOutcome::DroppedNew
            }
            ShedPolicy::DropOldest => {
                let old = self.q.pop_front().expect("full queue has a head");
                self.dropped += 1;
                self.push(f);
                AdmitOutcome::DroppedOldest(old)
            }
            ShedPolicy::Coalesce => {
                let tail = self.q.back_mut().expect("full queue has a tail");
                // The merged entry delivers the *newest* sensor data: it
                // takes the newcomer's seq/timestamp/deadline and counts
                // the superseded payload.
                tail.seq = f.seq;
                tail.arrived = f.arrived;
                tail.deadline = f.deadline;
                tail.coalesced += 1;
                self.coalesced += 1;
                AdmitOutcome::Coalesced
            }
        }
    }

    fn pop(&mut self) -> Option<QueuedFrame> {
        self.q.pop_front()
    }
}

/// The admission stage: all tenant queues plus the shed policy.
pub struct Admission {
    queues: Vec<TenantQueue>,
    shed: ShedPolicy,
}

impl Admission {
    pub fn new(wl: &WorkloadConfig) -> Admission {
        Admission {
            queues: (0..wl.tenants as usize)
                .map(|_| TenantQueue::new(wl.queue_cap as usize))
                .collect(),
            shed: wl.shed,
        }
    }

    pub fn num_tenants(&self) -> usize {
        self.queues.len()
    }

    pub fn tenant(&self, t: usize) -> &TenantQueue {
        &self.queues[t]
    }

    pub fn shed(&self) -> ShedPolicy {
        self.shed
    }

    /// Offer one arrival to its tenant's queue.
    pub fn offer(&mut self, a: FrameArrival) -> AdmitOutcome {
        let shed = self.shed;
        self.queues[a.tenant].offer(a, shed)
    }

    /// Head frame of tenant `t`'s queue (what a policy would serve next).
    pub fn head(&self, t: usize) -> Option<&QueuedFrame> {
        self.queues[t].head()
    }

    pub fn backlogged(&self, t: usize) -> bool {
        !self.queues[t].is_empty()
    }

    /// Dequeue tenant `t`'s head for service.
    pub fn pop(&mut self, t: usize) -> Option<QueuedFrame> {
        self.queues[t].pop()
    }

    /// Frames currently queued across all tenants.
    pub fn total_queued(&self) -> usize {
        self.queues.iter().map(TenantQueue::len).sum()
    }

    pub fn any_backlog(&self) -> bool {
        self.queues.iter().any(|q| !q.is_empty())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn arrival(tenant: usize, seq: u64, at: u64) -> FrameArrival {
        FrameArrival { at: SimTime(at), tenant, seq, deadline: SimTime(at + 100) }
    }

    fn adm(cap: u64, shed: ShedPolicy) -> Admission {
        let mut wl = WorkloadConfig::default();
        wl.tenants = 2;
        wl.queue_cap = cap;
        wl.shed = shed;
        Admission::new(&wl)
    }

    #[test]
    fn tail_drop_rejects_newcomer_at_cap() {
        let mut a = adm(2, ShedPolicy::TailDrop);
        assert_eq!(a.offer(arrival(0, 0, 10)), AdmitOutcome::Admitted);
        assert_eq!(a.offer(arrival(0, 1, 20)), AdmitOutcome::Admitted);
        assert_eq!(a.offer(arrival(0, 2, 30)), AdmitOutcome::DroppedNew);
        assert_eq!(a.tenant(0).len(), 2);
        assert_eq!(a.tenant(0).dropped, 1);
        // The stale head survived (tail-drop keeps the oldest data).
        assert_eq!(a.head(0).unwrap().seq, 0);
        // Other tenants unaffected.
        assert_eq!(a.offer(arrival(1, 0, 40)), AdmitOutcome::Admitted);
    }

    #[test]
    fn drop_oldest_evicts_head_and_admits() {
        let mut a = adm(2, ShedPolicy::DropOldest);
        a.offer(arrival(0, 0, 10));
        a.offer(arrival(0, 1, 20));
        match a.offer(arrival(0, 2, 30)) {
            AdmitOutcome::DroppedOldest(old) => assert_eq!(old.seq, 0),
            other => panic!("expected DroppedOldest, got {other:?}"),
        }
        assert_eq!(a.tenant(0).len(), 2);
        assert_eq!(a.head(0).unwrap().seq, 1, "freshest data wins");
        assert_eq!(a.tenant(0).dropped, 1);
        assert_eq!(a.tenant(0).admitted, 3);
    }

    #[test]
    fn coalesce_folds_into_tail_and_keeps_bound() {
        let mut a = adm(2, ShedPolicy::Coalesce);
        a.offer(arrival(0, 0, 10));
        a.offer(arrival(0, 1, 20));
        assert_eq!(a.offer(arrival(0, 2, 30)), AdmitOutcome::Coalesced);
        assert_eq!(a.offer(arrival(0, 3, 40)), AdmitOutcome::Coalesced);
        assert_eq!(a.tenant(0).len(), 2, "bound held");
        assert_eq!(a.tenant(0).coalesced, 2);
        assert_eq!(a.tenant(0).dropped, 0);
        // Head untouched; tail carries the newest payload + fold count.
        assert_eq!(a.head(0).unwrap().seq, 0);
        a.pop(0);
        let tail = a.head(0).unwrap();
        assert_eq!(tail.seq, 3);
        assert_eq!(tail.arrived, SimTime(40));
        assert_eq!(tail.coalesced, 2);
    }

    #[test]
    fn counters_balance_for_every_policy() {
        for shed in [ShedPolicy::TailDrop, ShedPolicy::DropOldest, ShedPolicy::Coalesce] {
            let mut a = adm(3, shed);
            let mut served = 0u64;
            for i in 0..20 {
                a.offer(arrival(0, i, 10 * i));
                if i % 3 == 0 && a.pop(0).is_some() {
                    served += 1;
                }
            }
            let q = a.tenant(0);
            assert!(q.len() <= q.cap());
            assert_eq!(q.offered, 20);
            // Every offered frame is served, queued, dropped or coalesced.
            assert_eq!(
                served + q.len() as u64 + q.dropped + q.coalesced,
                q.offered,
                "{shed:?}"
            );
            assert!(q.max_depth <= q.cap());
        }
    }

    #[test]
    fn queue_cap_one_edge_case() {
        // Coalesce with cap 1: the single slot keeps absorbing frames.
        let mut a = adm(1, ShedPolicy::Coalesce);
        a.offer(arrival(0, 0, 10));
        for i in 1..5 {
            assert_eq!(a.offer(arrival(0, i, 10 + i)), AdmitOutcome::Coalesced);
        }
        assert_eq!(a.tenant(0).len(), 1);
        assert_eq!(a.head(0).unwrap().seq, 4);
        assert_eq!(a.head(0).unwrap().coalesced, 4);
        assert_eq!(a.total_queued(), 1);
        assert!(a.any_backlog());
        a.pop(0);
        assert!(!a.any_backlog());
    }
}
