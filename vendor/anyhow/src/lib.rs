//! Offline, API-compatible subset of the `anyhow` crate.
//!
//! The build sandbox has no network access to crates.io, so the real crate
//! cannot be fetched; this vendored shim provides the slice of its API the
//! workspace uses: [`Error`], [`Result`], the [`Context`] extension trait,
//! and the `anyhow!` / `bail!` / `ensure!` macros. Semantics match the
//! upstream crate for these uses:
//!
//! * `Error` is an opaque, `Send + Sync` error value that does **not**
//!   implement `std::error::Error` itself (that is what makes the blanket
//!   `From<E: std::error::Error>` conversion coherent — same trick as
//!   upstream);
//! * `{:#}` display prints the context chain joined with `: `;
//! * `?` converts any `std::error::Error + Send + Sync + 'static` into
//!   `Error`.

use std::fmt;

/// An opaque error: a chain of context strings, outermost first.
pub struct Error {
    /// Context chain, outermost (most recently attached) first.
    chain: Vec<String>,
}

impl Error {
    /// Build an error from a printable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { chain: vec![message.to_string()] }
    }

    /// Attach higher-level context (outermost).
    pub fn context<C: fmt::Display>(mut self, context: C) -> Error {
        self.chain.insert(0, context.to_string());
        self
    }

    /// The outermost message only (what bare `{}` shows).
    pub fn root_message(&self) -> &str {
        &self.chain[0]
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        if f.alternate() {
            // `{:#}`: the whole chain, `outer: inner: ...` like upstream.
            write!(f, "{}", self.chain.join(": "))
        } else {
            write!(f, "{}", self.chain[0])
        }
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        writeln!(f, "{}", self.chain[0])?;
        if self.chain.len() > 1 {
            writeln!(f, "\nCaused by:")?;
            for (i, c) in self.chain[1..].iter().enumerate() {
                writeln!(f, "    {i}: {c}")?;
            }
        }
        Ok(())
    }
}

impl<E> From<E> for Error
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn from(e: E) -> Error {
        let mut chain = vec![e.to_string()];
        let mut src = e.source();
        while let Some(s) = src {
            chain.push(s.to_string());
            src = s.source();
        }
        Error { chain }
    }
}

/// `anyhow::Result<T>`: `Result` defaulting the error type to [`Error`].
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Extension trait adding `.context(...)` / `.with_context(...)`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T>;
}

impl<T, E> Context<T> for std::result::Result<T, E>
where
    E: std::error::Error + Send + Sync + 'static,
{
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.map_err(|e| Error::from(e).context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.map_err(|e| Error::from(e).context(f()))
    }
}

impl<T> Context<T> for Option<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T> {
        self.ok_or_else(|| Error::msg(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T> {
        self.ok_or_else(|| Error::msg(f()))
    }
}

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($($arg:tt)*) => {
        $crate::Error::msg(format!($($arg)*))
    };
}

/// Return early with a formatted [`Error`].
#[macro_export]
macro_rules! bail {
    ($($arg:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($arg)*))
    };
}

/// Return early with a formatted [`Error`] unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr, $($arg:tt)*) => {
        if !($cond) {
            $crate::bail!($($arg)*);
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_err() -> std::io::Error {
        std::io::Error::new(std::io::ErrorKind::NotFound, "gone")
    }

    #[test]
    fn question_mark_converts_std_errors() {
        fn f() -> Result<()> {
            Err(io_err())?;
            Ok(())
        }
        assert_eq!(f().unwrap_err().to_string(), "gone");
    }

    #[test]
    fn context_chains_and_alternate_display() {
        let e: Result<()> = Err(io_err());
        let e = e.context("reading config").unwrap_err();
        assert_eq!(format!("{e}"), "reading config");
        assert_eq!(format!("{e:#}"), "reading config: gone");
    }

    #[test]
    fn macros_work() {
        fn f(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 5 {
                bail!("five is right out");
            }
            Ok(x)
        }
        assert_eq!(f(3).unwrap(), 3);
        assert!(f(11).unwrap_err().to_string().contains("too big"));
        assert!(f(5).unwrap_err().to_string().contains("right out"));
        let e = anyhow!("plain {}", 7);
        assert_eq!(e.to_string(), "plain 7");
    }

    #[test]
    fn option_context() {
        let v: Option<u32> = None;
        let e = v.context("missing").unwrap_err();
        assert_eq!(e.to_string(), "missing");
    }
}
